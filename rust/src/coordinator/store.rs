//! Per-worker matrix storage: each worker rank holds its row-block of
//! every live distributed matrix (the server-side half of the `AlMatrix`
//! proxy scheme — data stays put between routines; only handles travel).
//!
//! Blocks are namespaced by owning session: matrix ids are globally
//! unique (the driver hands them out from one counter), but every block
//! records the session that created it and which slot of the layout this
//! worker fills (the session's *group-local* rank — with session-scoped
//! worker groups a worker's global rank no longer indexes
//! `layout.ranges`). Session teardown frees exactly that session's
//! blocks without touching any other tenant's.
//!
//! ## Locking model (the ingest hot path)
//!
//! The store itself is only a directory: an `RwLock`ed id → `Arc<Block>`
//! map held for microseconds per lookup. Payload writes never touch it —
//! each [`Block`] carries its own ingest state and a small array of
//! *stripe locks* over its local row range, so
//!
//! * executors streaming **different matrices** into one worker share
//!   nothing but the read lock on the map;
//! * executors streaming **disjoint row ranges of one matrix** land on
//!   disjoint stripes and copy concurrently;
//! * overlapping writes (a misbehaving client) serialize on their shared
//!   stripes instead of racing.
//!
//! Writers never materialize a reference over the whole payload buffer —
//! that would alias between concurrent writers even on disjoint stripes.
//! Each write derives a `&mut [f64]` over exactly its locked span from a
//! raw base pointer captured at construction (`Block::base`), so the
//! exclusive references of concurrent writers are disjoint by
//! construction.
//!
//! Sealing is the ingest/compute barrier, in three steps: `seal` flips
//! `sealed` under the state mutex (new writers abort — they re-check it
//! *after* acquiring their stripes), takes every stripe lock once to
//! wait out in-flight writers (who copy AND account while holding their
//! stripes), and only then sets `readable` — the flag every reader
//! gates on, so a read can never overlap a straggling pre-seal copy. A
//! readable block is immutable, which is what lets pulls stream borrowed
//! spans ([`Block::read_span`]) straight from the block into the socket
//! buffer with zero copies on the worker side.

use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use crate::distmat::{LocalMatrix, RowBlockLayout};
use crate::protocol::wire::copy_le_f64s;

/// Stripe-lock count per block: enough for the handful of concurrent
/// executor streams a worker realistically sees, cheap enough to sit on
/// every block.
const INGEST_STRIPES: usize = 8;

#[derive(Debug, Default)]
struct IngestState {
    rows_received: u64,
    /// Writers stop here: set at the start of `seal`, checked by every
    /// writer after it acquires its stripes.
    sealed: bool,
    /// Readers start here: set at the END of `seal`, after the stripe
    /// barrier has waited out every in-flight writer — the window where
    /// `sealed` is already true but a pre-seal writer is still copying
    /// must not be readable (that read would race the copy).
    readable: bool,
}

/// One worker's block of a distributed matrix. Immutable metadata plus
/// interior-mutable payload storage guarded by the stripe/seal protocol
/// described in the module docs.
pub struct Block {
    pub id: u64,
    pub layout: RowBlockLayout,
    /// Index of this worker's range in `layout.ranges`: the owning
    /// session's group-local rank for this worker.
    pub slot: usize,
    /// Session that owns this matrix.
    pub session: u64,
    pub name: String,
    /// Global rank of the worker holding this block (error messages).
    rank: usize,
    state: Mutex<IngestState>,
    stripes: [Mutex<()>; INGEST_STRIPES],
    /// This rank's rows (`layout.ranges[slot]`), row-major. Mutated only
    /// through [`Block::write_span`] before sealing; immutable after.
    data: UnsafeCell<LocalMatrix>,
    /// Raw pointer to `data`'s element buffer, captured at construction
    /// (the buffer is fixed-size and never reallocated, so it stays
    /// valid for the block's lifetime). Writers derive their span's
    /// `&mut [f64]` from this instead of creating `&mut LocalMatrix`
    /// through the cell — a whole-buffer exclusive reference would alias
    /// between concurrent writers on disjoint stripes.
    base: *mut f64,
    /// Element count behind `base` (span bounds sanity checks).
    len: usize,
}

// Safety: the raw `base` pointer (which suppresses the auto impls)
// points into the heap buffer owned by `data`, so it moves with the
// block. Payload bytes are only written through per-span `&mut [f64]`
// slices derived from `base` while holding the stripe locks covering
// exactly those rows and only while not `sealed` (checked under the
// state mutex after stripe acquisition), so concurrent writers' spans —
// and therefore their exclusive references — are disjoint. Readers
// require `readable`, which `seal` sets only after a full stripe
// barrier has waited out every in-flight writer — so reads and writes
// can never overlap, and the state mutex publishes the writes to
// readers. See the module docs.
unsafe impl Send for Block {}
unsafe impl Sync for Block {}

impl std::fmt::Debug for Block {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Block")
            .field("id", &self.id)
            .field("name", &self.name)
            .field("slot", &self.slot)
            .field("session", &self.session)
            .field("sealed", &self.sealed())
            .field("rows_received", &self.rows_received())
            .finish()
    }
}

impl Block {
    fn new(
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        slot: usize,
        session: u64,
        rank: usize,
        local: Option<LocalMatrix>,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            slot < layout.ranges.len(),
            "slot {slot} outside layout of {} ranges",
            layout.ranges.len()
        );
        let (a, b) = layout.ranges[slot];
        let (mut local, sealed, rows_received) = match local {
            Some(m) => {
                anyhow::ensure!(
                    m.rows() == b - a && m.cols() == layout.cols,
                    "block shape {}x{} does not match layout slot {}x{} on rank {rank}",
                    m.rows(),
                    m.cols(),
                    b - a,
                    layout.cols,
                );
                let rows = m.rows() as u64;
                (m, true, rows)
            }
            None => (LocalMatrix::zeros(b - a, layout.cols), false, 0),
        };
        // capture the element buffer's base pointer while we still own
        // the matrix uniquely; moving the LocalMatrix into the cell moves
        // only its header, not the heap buffer the pointer targets
        let buf = local.data_mut();
        let len = buf.len();
        let base = buf.as_mut_ptr();
        Ok(Block {
            id,
            layout,
            slot,
            session,
            name: name.to_string(),
            rank,
            state: Mutex::new(IngestState {
                rows_received,
                sealed,
                readable: sealed,
            }),
            stripes: Default::default(),
            data: UnsafeCell::new(local),
            base,
            len,
        })
    }

    pub fn sealed(&self) -> bool {
        self.state.lock().unwrap().sealed
    }

    /// True once `seal` has fully completed (flag flipped AND the stripe
    /// barrier passed) — the gate every reader checks. Distinct from
    /// [`sealed`](Self::sealed), which flips first to stop writers.
    fn readable(&self) -> bool {
        self.state.lock().unwrap().readable
    }

    pub fn rows_received(&self) -> u64 {
        self.state.lock().unwrap().rows_received
    }

    /// Bounds-check a global row span against this block's range; returns
    /// the local start row.
    fn span_local_start(&self, start_row: u64, nrows: usize) -> crate::Result<usize> {
        let (lo, hi) = self.layout.ranges[self.slot];
        let start = usize::try_from(start_row)
            .map_err(|_| anyhow::anyhow!("row index {start_row} out of range"))?;
        let end = start
            .checked_add(nrows)
            .ok_or_else(|| anyhow::anyhow!("row span end overflows"))?;
        anyhow::ensure!(
            start >= lo && end <= hi,
            "rows [{start}, {end}) outside rank {} range [{lo}, {hi})",
            self.rank
        );
        Ok(start - lo)
    }

    /// Stripe index owning local row `row` (rows divide evenly-ish across
    /// [`INGEST_STRIPES`] fixed bands).
    fn stripe_of(&self, row: usize, local_rows: usize) -> usize {
        debug_assert!(local_rows > 0);
        (row * INGEST_STRIPES / local_rows).min(INGEST_STRIPES - 1)
    }

    /// Copy `nrows` rows into the block at `start_row` (global), with the
    /// writer-side locking protocol: acquire covering stripes in order,
    /// re-check `sealed`, copy, then account under the state mutex.
    fn write_span(
        &self,
        start_row: u64,
        ncols: usize,
        nrows: usize,
        fill: impl FnOnce(&mut [f64]),
    ) -> crate::Result<()> {
        anyhow::ensure!(
            ncols == self.layout.cols,
            "row width {ncols} != matrix cols {}",
            self.layout.cols
        );
        let local_start = self.span_local_start(start_row, nrows)?;
        if nrows == 0 {
            return Ok(());
        }
        let (lo, hi) = self.layout.ranges[self.slot];
        let local_rows = hi - lo;
        let first = self.stripe_of(local_start, local_rows);
        let last = self.stripe_of(local_start + nrows - 1, local_rows);
        let guards: Vec<_> =
            (first..=last).map(|i| self.stripes[i].lock().unwrap()).collect();
        {
            let st = self.state.lock().unwrap();
            anyhow::ensure!(!st.sealed, "matrix {} is sealed", self.id);
        }
        debug_assert!((local_start + nrows) * ncols <= self.len);
        // Safety: the stripes covering [local_start, local_start+nrows)
        // are held, so this element range is ours alone; every concurrent
        // writer builds its slice the same way over its own (disjoint)
        // span from the raw `base` pointer, so no exclusive reference
        // over the whole buffer — which would alias between writers —
        // ever exists. Readers are excluded because the block is not
        // `readable` yet — that flag is set only after `seal`'s stripe
        // barrier has waited us out.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(
                self.base.add(local_start * ncols),
                nrows * ncols,
            )
        };
        fill(dst);
        // account while still holding the stripes: once `seal`'s barrier
        // passes our stripes, our rows are guaranteed to be in the count
        self.state.lock().unwrap().rows_received += nrows as u64;
        drop(guards);
        Ok(())
    }

    /// Write incoming rows (global indices) given as f64s.
    pub fn write_rows(
        &self,
        start_row: u64,
        ncols: usize,
        data: &[f64],
    ) -> crate::Result<()> {
        anyhow::ensure!(ncols > 0 && data.len() % ncols == 0, "ragged row payload");
        self.write_span(start_row, ncols, data.len() / ncols, |dst| {
            dst.copy_from_slice(data)
        })
    }

    /// Write incoming rows straight from little-endian wire bytes — the
    /// single-copy ingest path (frame receive buffer → block storage).
    pub fn write_rows_bytes(
        &self,
        start_row: u64,
        ncols: usize,
        payload: &[u8],
    ) -> crate::Result<()> {
        anyhow::ensure!(
            ncols > 0 && payload.len() % (ncols * 8) == 0,
            "ragged row payload"
        );
        self.write_span(start_row, ncols, payload.len() / (ncols * 8), |dst| {
            copy_le_f64s(payload, dst)
        })
    }

    /// Borrow rows (global indices) out of a sealed block — the zero-copy
    /// worker side of a streaming pull. Fails on unsealed blocks (ingest
    /// still running ⇒ the span could be mid-write).
    pub fn read_span(&self, start_row: u64, nrows: usize) -> crate::Result<&[f64]> {
        anyhow::ensure!(
            self.readable(),
            "matrix {} is still being ingested (not sealed)",
            self.id
        );
        let local_start = self.span_local_start(start_row, nrows)?;
        let ncols = self.layout.cols;
        // Safety: readable ⇒ the seal barrier has waited out every
        // writer and nothing mutates the payload again, so shared
        // borrows are sound.
        let local = unsafe { &*self.data.get() };
        Ok(&local.data()[local_start * ncols..(local_start + nrows) * ncols])
    }

    /// Copy rows (global indices) out of a sealed block.
    pub fn read_rows(&self, start_row: u64, nrows: usize) -> crate::Result<Vec<f64>> {
        Ok(self.read_span(start_row, nrows)?.to_vec())
    }

    /// Clone this rank's sealed block for compute (routines never hold
    /// store or block locks while working).
    pub fn snapshot(&self) -> crate::Result<(RowBlockLayout, LocalMatrix)> {
        anyhow::ensure!(self.readable(), "matrix {} is not sealed yet", self.id);
        // Safety: readable ⇒ immutable, as in `read_span`.
        let local = unsafe { &*self.data.get() };
        Ok((self.layout.clone(), local.clone()))
    }

    /// Freeze the block: no further writes land after this returns, every
    /// row written before it is in the returned count, and only now do
    /// readers get the green light.
    fn seal(&self) -> u64 {
        self.state.lock().unwrap().sealed = true;
        // barrier: wait out writers that passed their seal check before
        // the flag flipped (they hold their stripes while copying AND
        // accounting, so after this loop the payload is quiescent and
        // every landed row is counted)
        for s in &self.stripes {
            drop(s.lock().unwrap());
        }
        // only now may readers touch the payload; the same lock publishes
        // the in-flight writers' bytes and counts to them
        let mut st = self.state.lock().unwrap();
        st.readable = true;
        st.rows_received
    }
}

/// Matrix-id → block map for one worker rank. Interior-locked: lookups
/// take a short read lock, payload writes synchronize per block (see the
/// module docs), so the store itself never serializes concurrent
/// executor streams.
#[derive(Debug, Default)]
pub struct MatrixStore {
    rank: usize,
    blocks: RwLock<HashMap<u64, Arc<Block>>>,
}

impl MatrixStore {
    pub fn new(rank: usize) -> Self {
        MatrixStore { rank, blocks: RwLock::new(HashMap::new()) }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    fn add(&self, id: u64, block: Block) -> crate::Result<()> {
        let mut blocks = self.blocks.write().unwrap();
        anyhow::ensure!(
            !blocks.contains_key(&id),
            "matrix id {id} already exists on rank {}",
            self.rank
        );
        blocks.insert(id, Arc::new(block));
        Ok(())
    }

    /// Allocate a zeroed, unsealed block for ingest. `slot` is this
    /// worker's index into `layout.ranges` (the session's group-local
    /// rank); `session` namespaces the block for teardown.
    pub fn alloc(
        &self,
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        slot: usize,
        session: u64,
    ) -> crate::Result<()> {
        self.add(id, Block::new(id, name, layout, slot, session, self.rank, None)?)
    }

    /// Insert a fully-formed (already computed) block — routine outputs.
    pub fn insert(
        &self,
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        local: LocalMatrix,
        slot: usize,
        session: u64,
    ) -> crate::Result<()> {
        self.add(
            id,
            Block::new(id, name, layout, slot, session, self.rank, Some(local))?,
        )
    }

    /// Look a block up under the read lock; the returned handle outlives
    /// the lock (pulls stream from it, ingest writes through it).
    pub fn get(&self, id: u64) -> crate::Result<Arc<Block>> {
        self.blocks
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("matrix {id} not found on rank {}", self.rank))
    }

    /// Write incoming rows (global indices) into an unsealed block.
    pub fn write_rows(
        &self,
        id: u64,
        start_row: u64,
        ncols: usize,
        data: &[f64],
    ) -> crate::Result<()> {
        self.get(id)?.write_rows(start_row, ncols, data)
    }

    /// Read rows (global indices) out of a sealed block.
    pub fn read_rows(&self, id: u64, start_row: u64, nrows: usize) -> crate::Result<Vec<f64>> {
        self.get(id)?.read_rows(start_row, nrows)
    }

    pub fn seal(&self, id: u64) -> crate::Result<u64> {
        Ok(self.get(id)?.seal())
    }

    pub fn free(&self, id: u64) -> bool {
        self.blocks.write().unwrap().remove(&id).is_some()
    }

    /// Drop every block owned by `session` (teardown); returns how many
    /// were freed. Other sessions' blocks are untouched.
    pub fn free_session(&self, session: u64) -> usize {
        let mut blocks = self.blocks.write().unwrap();
        let before = blocks.len();
        blocks.retain(|_, b| b.session != session);
        before - blocks.len()
    }

    pub fn ids(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.blocks.read().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.blocks.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.read().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SID: u64 = 11;

    fn layout2() -> RowBlockLayout {
        RowBlockLayout::even(10, 3, 2)
    }

    #[test]
    fn ingest_flow() {
        let s = MatrixStore::new(1); // slot 1 owns rows [5, 10)
        s.alloc(7, "X", layout2(), 1, SID).unwrap();
        s.write_rows(7, 5, 3, &[1.0; 6]).unwrap(); // rows 5,6
        s.write_rows(7, 7, 3, &[2.0; 9]).unwrap(); // rows 7,8,9
        assert_eq!(s.seal(7).unwrap(), 5);
        let b = s.get(7).unwrap();
        let (_, local) = b.snapshot().unwrap();
        assert_eq!(local.get(0, 0), 1.0);
        assert_eq!(local.get(2, 2), 2.0);
        // reads are in global coordinates
        assert_eq!(s.read_rows(7, 9, 1).unwrap(), vec![2.0, 2.0, 2.0]);
        // zero-copy span points at the same rows
        assert_eq!(b.read_span(9, 1).unwrap(), &[2.0, 2.0, 2.0]);
    }

    #[test]
    fn byte_ingest_matches_f64_ingest() {
        let s = MatrixStore::new(0); // slot 0 owns rows [0, 5)
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        let rows = [1.5f64, -2.5, 3.0, 4.0, 5.0, 6.5];
        let mut bytes = Vec::new();
        for x in &rows {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        s.get(1).unwrap().write_rows_bytes(0, 3, &bytes).unwrap();
        s.seal(1).unwrap();
        assert_eq!(s.read_rows(1, 0, 2).unwrap(), rows);
    }

    #[test]
    fn slot_decouples_from_global_rank() {
        // a worker with global rank 5 fills slot 0 of a 2-range layout
        // (session-scoped groups: group-local rank != global rank)
        let s = MatrixStore::new(5);
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        s.write_rows(1, 0, 3, &[3.0; 15]).unwrap(); // rows [0, 5)
        assert_eq!(s.seal(1).unwrap(), 5);
        assert_eq!(s.read_rows(1, 4, 1).unwrap(), vec![3.0, 3.0, 3.0]);
        // rows of the other slot are rejected
        assert!(s.write_rows(1, 5, 3, &[0.0; 3]).is_err());
    }

    #[test]
    fn rejects_bad_writes() {
        let s = MatrixStore::new(0); // slot 0 owns rows [0, 5)
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        assert!(s.alloc(1, "X", layout2(), 0, SID).is_err()); // duplicate id
        assert!(s.alloc(2, "X", layout2(), 9, SID).is_err()); // bad slot
        assert!(s.write_rows(1, 4, 3, &[0.0; 6]).is_err()); // crosses range end
        assert!(s.write_rows(1, 0, 2, &[0.0; 2]).is_err()); // wrong width
        assert!(s.write_rows(2, 0, 3, &[0.0; 3]).is_err()); // unknown id
        s.seal(1).unwrap();
        assert!(s.write_rows(1, 0, 3, &[0.0; 3]).is_err()); // sealed
        assert!(s.read_rows(1, 4, 2).is_err()); // read crosses range
    }

    #[test]
    fn reads_require_seal() {
        let s = MatrixStore::new(0);
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        let b = s.get(1).unwrap();
        assert!(b.read_span(0, 1).is_err());
        assert!(b.snapshot().is_err());
        s.seal(1).unwrap();
        assert!(b.read_span(0, 1).is_ok());
        assert!(b.snapshot().is_ok());
    }

    #[test]
    fn insert_checks_shape() {
        let s = MatrixStore::new(0);
        let l = layout2();
        assert!(s
            .insert(3, "W", l.clone(), LocalMatrix::zeros(4, 3), 0, SID)
            .is_err());
        s.insert(3, "W", l, LocalMatrix::zeros(5, 3), 0, SID).unwrap();
        assert!(s.get(3).unwrap().sealed());
        assert!(s.free(3));
        assert!(!s.free(3));
    }

    #[test]
    fn free_session_is_scoped() {
        let s = MatrixStore::new(0);
        s.alloc(1, "A", layout2(), 0, 100).unwrap();
        s.alloc(2, "B", layout2(), 0, 100).unwrap();
        s.alloc(3, "C", layout2(), 1, 200).unwrap();
        assert_eq!(s.free_session(100), 2);
        assert_eq!(s.ids(), vec![3]);
        assert_eq!(s.free_session(100), 0);
        assert_eq!(s.free_session(200), 1);
        assert!(s.is_empty());
    }

    #[test]
    fn seal_racing_a_writer_counts_exactly_the_landed_rows() {
        // a seal fired mid-stream must (a) include every write that
        // returned Ok, (b) reject everything after, (c) never tear data
        let layout = RowBlockLayout::even(4096, 1, 1);
        let s = Arc::new(MatrixStore::new(0));
        s.alloc(5, "X", layout, 0, SID).unwrap();
        let writer = {
            let s = s.clone();
            std::thread::spawn(move || {
                let mut landed = 0u64;
                for row in 0..4096u64 {
                    match s.write_rows(5, row, 1, &[row as f64]) {
                        Ok(()) => landed += 1,
                        Err(_) => break, // sealed mid-stream
                    }
                }
                landed
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(2));
        let sealed_count = s.seal(5).unwrap();
        let landed = writer.join().unwrap();
        assert_eq!(sealed_count, landed, "seal lost or invented rows");
        assert_eq!(s.get(5).unwrap().rows_received(), landed);
        // rows that landed read back intact
        for row in 0..landed {
            assert_eq!(s.read_rows(5, row, 1).unwrap(), vec![row as f64]);
        }
    }

    #[test]
    fn concurrent_disjoint_writers_land_every_row() {
        // N threads interleave writes to disjoint row runs of one block;
        // the stripe protocol must lose nothing and count every row
        let layout = RowBlockLayout::even(64, 4, 1);
        let s = Arc::new(MatrixStore::new(0));
        s.alloc(9, "X", layout, 0, SID).unwrap();
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                // thread t owns rows {t, t+4, t+8, ...}, written one at a time
                let mut row = t;
                while row < 64 {
                    let vals = [row as f64; 4];
                    s.write_rows(9, row, 4, &vals).unwrap();
                    row += 4;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.seal(9).unwrap(), 64);
        for row in 0..64u64 {
            assert_eq!(s.read_rows(9, row, 1).unwrap(), vec![row as f64; 4]);
        }
    }
}
