//! Synthetic TIMIT-like speech-classification corpus (paper §4.1).
//!
//! The real pre-processed TIMIT has 2,251,569 training examples, 440 raw
//! features, and 147 phone classes. What the CG experiment needs from it:
//! an over-determined least-squares problem whose raw features are weakly
//! expressive (so random-feature expansion helps) and whose one-hot label
//! matrix has the class structure the W-matrix solve assumes. The
//! generator draws class centroids on a sphere and samples points with
//! within-class noise — classification is learnable but not linearly
//! trivial, and accuracy improves with the number of random features,
//! which is the paper's Table 1 narrative.

use crate::distmat::LocalMatrix;
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct TimitSpec {
    pub train_rows: usize,
    pub test_rows: usize,
    /// Raw feature count (paper: 440).
    pub raw_features: usize,
    /// Number of classes (paper: 147).
    pub classes: usize,
    /// Within-class noise scale (higher = harder problem).
    pub noise: f64,
    pub seed: u64,
}

impl Default for TimitSpec {
    fn default() -> Self {
        // 1/137 of the paper's corpus; bench configs scale further.
        // noise 5.0 places accuracy meaningfully below 1.0 (the centroid
        // separation in 440 dims is ~√(2·440) ≈ 30), so the accuracy
        // columns in the drivers are informative.
        TimitSpec {
            train_rows: 16_384,
            test_rows: 2_048,
            raw_features: 440,
            classes: 32,
            noise: 5.0,
            seed: 0x7131_7400,
        }
    }
}

/// A generated corpus: features, one-hot labels, and the integer class of
/// every row (train then test).
pub struct TimitData {
    pub x_train: LocalMatrix,
    pub y_train: LocalMatrix,
    pub labels_train: Vec<usize>,
    pub x_test: LocalMatrix,
    pub labels_test: Vec<usize>,
}

impl TimitSpec {
    pub fn generate(&self) -> TimitData {
        let mut rng = Rng::new(self.seed);
        // class centroids on a scaled sphere
        let centroids = LocalMatrix::from_fn(self.classes, self.raw_features, |_, _| {
            rng.normal()
        });

        let gen_split = |rows: usize, stream: u64| {
            let mut rng = Rng::new(self.seed).derive(stream);
            let mut x = LocalMatrix::zeros(rows, self.raw_features);
            let mut labels = Vec::with_capacity(rows);
            for i in 0..rows {
                let cls = rng.below(self.classes);
                labels.push(cls);
                let row = x.row_mut(i);
                let c = centroids.row(cls);
                for (j, v) in row.iter_mut().enumerate() {
                    *v = c[j] + self.noise * rng.normal();
                }
            }
            (x, labels)
        };

        let (x_train, labels_train) = gen_split(self.train_rows, 1);
        let (x_test, labels_test) = gen_split(self.test_rows, 2);

        let mut y_train = LocalMatrix::zeros(self.train_rows, self.classes);
        for (i, &cls) in labels_train.iter().enumerate() {
            y_train.set(i, cls, 1.0);
        }

        TimitData { x_train, y_train, labels_train, x_test, labels_test }
    }

    /// A reasonable Gaussian-kernel bandwidth for this corpus: the random
    /// Fourier phases `γ·xᵀω` stay within a few radians for typical point
    /// distances (`‖x‖ ≈ √d·(1 + noise²)^½`), which keeps the cosine
    /// features informative instead of aliasing.
    pub fn default_gamma(&self) -> f64 {
        let typical_norm =
            ((self.raw_features as f64) * (1.0 + self.noise * self.noise)).sqrt();
        1.0 / typical_norm
    }
}

/// Classification accuracy of scores `X·W` against integer labels
/// (argmax per row — how the paper's 147-dim label vectors are read).
pub fn accuracy(scores: &LocalMatrix, labels: &[usize]) -> f64 {
    assert_eq!(scores.rows(), labels.len());
    let mut correct = 0usize;
    for (i, &want) in labels.iter().enumerate() {
        let row = scores.row(i);
        let mut best = 0usize;
        for j in 1..row.len() {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best == want {
            correct += 1;
        }
    }
    correct as f64 / labels.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_one_hot() {
        let spec = TimitSpec {
            train_rows: 64,
            test_rows: 16,
            raw_features: 10,
            classes: 4,
            noise: 0.5,
            seed: 3,
        };
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.x_train, b.x_train);
        assert_eq!(a.labels_test, b.labels_test);
        // labels one-hot
        for i in 0..64 {
            let row = a.y_train.row(i);
            assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(row.iter().sum::<f64>(), 1.0);
            assert_eq!(row[a.labels_train[i]], 1.0);
        }
    }

    #[test]
    fn linear_ridge_beats_chance_on_easy_data() {
        let spec = TimitSpec {
            train_rows: 256,
            test_rows: 64,
            raw_features: 16,
            classes: 4,
            noise: 0.3,
            seed: 5,
        };
        let d = spec.generate();
        // one-rank ridge fit on the raw features
        let comms = crate::collectives::LocalComm::group(1, None);
        let mut e = crate::compute::NativeEngine::new();
        let res = crate::linalg::cg_solve(
            &comms[0],
            &mut e,
            &d.x_train,
            &d.y_train,
            256,
            &crate::linalg::CgOptions { lambda: 1e-4, tol: 1e-10, max_iters: 200 },
        )
        .unwrap();
        let mut scores = LocalMatrix::zeros(64, 4);
        scores.gemm_nn(&d.x_test, &res.w);
        let acc = accuracy(&scores, &d.labels_test);
        assert!(acc > 0.5, "accuracy {acc} should beat 0.25 chance easily");
    }

    #[test]
    fn accuracy_helper() {
        let scores = LocalMatrix::from_data(2, 3, vec![0.1, 0.9, 0.0, 1.0, 0.2, 0.3]);
        assert_eq!(accuracy(&scores, &[1, 0]), 1.0);
        assert_eq!(accuracy(&scores, &[0, 0]), 0.5);
    }
}
