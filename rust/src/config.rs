//! Layered configuration: compiled defaults → config file → `--set k=v`
//! overrides. Every knob the benches sweep lives here so EXPERIMENTS.md can
//! record the exact configuration of each table row.
//!
//! The file format is the flat `key = value` subset of TOML (comments with
//! `#`, optional `[section]` headers that prefix keys with `section.`) —
//! serde is not in the offline vendor set, and the paper's configuration
//! surface is small enough that a real TOML parser buys nothing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

/// Which compute engine the workers run (DESIGN.md ablation #1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Blocked pure-rust GEMM (no XLA on the hot path) — the floor.
    Native,
    /// AOT artifacts lowered from the pure-jnp graphs (XLA `dot`).
    Xla,
    /// AOT artifacts lowered from the Pallas kernels (`interpret=True`).
    Pallas,
    /// Adaptive: a calibrated cost model picks native vs XLA per call
    /// (`compute::dispatch`); degrades to native when no artifacts exist.
    Auto,
}

impl EngineKind {
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "native" => EngineKind::Native,
            "xla" => EngineKind::Xla,
            "pallas" => EngineKind::Pallas,
            "auto" => EngineKind::Auto,
            other => bail!("unknown engine {other:?} (native|xla|pallas|auto)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Xla => "xla",
            EngineKind::Pallas => "pallas",
            EngineKind::Auto => "auto",
        }
    }
}

/// Socket-transfer tuning (DESIGN.md ablation #3). The first two knobs
/// are negotiable per session (protocol v3): a client's handshake may
/// request its own values, which the server clamps to the `max_*` limits
/// below and echoes back in the ack.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransferConfig {
    /// Matrix rows batched into one wire frame.
    pub rows_per_frame: usize,
    /// Userspace buffer in front of the socket.
    pub buf_bytes: usize,
    /// Server-side cap on a client's negotiated `rows_per_frame`.
    pub max_rows_per_frame: usize,
    /// Server-side cap on a client's negotiated `buf_bytes`.
    pub max_buf_bytes: usize,
    /// Rows covered by one ranged `PullRows` request (the streaming-pull
    /// stripe; each stripe streams back as many frames + one trailer).
    pub pull_stripe_rows: usize,
    /// Max outstanding ranged pull requests per worker link (windowed
    /// pipelining: the worker prepares stripe k+1 while the client
    /// drains stripe k, so the socket never idles). This is the hard cap;
    /// the effective window adapts to the stripe size — see
    /// [`TransferConfig::pull_window_bytes`].
    pub pull_window: usize,
    /// Byte budget for in-flight (requested but undrained) pull stripes
    /// per worker link. The effective window is
    /// `pull_window_bytes / stripe_bytes`, clamped to `[1, pull_window]`,
    /// so narrow matrices pipeline deeply while wide ones stop queueing
    /// stripes the client cannot drain (adaptive pull-side backpressure).
    /// 0 disables the byte budget (always use `pull_window`).
    pub pull_window_bytes: usize,
}

impl TransferConfig {
    /// Resolve a client's requested `(rows_per_frame, buf_bytes)` — 0
    /// means "server default" — against this (server-side) config's
    /// limits. Returns the effective per-session config.
    pub fn negotiate(&self, rows_per_frame: u32, buf_bytes: u64) -> TransferConfig {
        let rows = if rows_per_frame == 0 {
            self.rows_per_frame
        } else {
            rows_per_frame as usize
        };
        let buf = if buf_bytes == 0 {
            self.buf_bytes
        } else {
            // saturate the u64 -> usize conversion: on 32-bit targets a
            // plain `as` cast wraps (2^32 -> 0), turning an oversized
            // request into the 4 KiB floor instead of the max
            usize::try_from(buf_bytes).unwrap_or(usize::MAX)
        };
        TransferConfig {
            rows_per_frame: rows.clamp(1, self.max_rows_per_frame.max(1)),
            buf_bytes: buf.clamp(4 << 10, self.max_buf_bytes.max(4 << 10)),
            ..self.clone()
        }
    }

    /// Clamp a data-connection's requested pull-frame granularity
    /// (0 = server default) to the server limits.
    pub fn effective_frame_rows(&self, requested: u32) -> usize {
        if requested == 0 {
            self.rows_per_frame.max(1)
        } else {
            (requested as usize).clamp(1, self.max_rows_per_frame.max(1))
        }
    }
}

/// The sparklite overhead model (DESIGN.md §2): what a Spark stage pays
/// beyond its compute on the paper's testbed, scaled to this one. Defaults
/// calibrated against Table 2 / Gittens et al. 2016: per-iteration Spark
/// overheads of tens of seconds at 20–40 nodes, dominated by scheduler
/// delay and task-start costs, scaled by ~1/50 to this single-box setup.
#[derive(Debug, Clone)]
pub struct OverheadConfig {
    /// Fixed scheduler delay per BSP stage (s).
    pub scheduler_delay_s: f64,
    /// Task launch + deserialization cost per task (s).
    pub task_launch_s: f64,
    /// Result serialization throughput (bytes/s) charged per task output.
    pub serde_bytes_per_s: f64,
    /// Coefficient of variation of task-duration jitter (stragglers).
    pub straggler_cv: f64,
}

/// Modeled interconnect for simulated-cluster-time accounting (the box has
/// one core; DESIGN.md §2 "Cori" row). Roughly a tenth of Aries: 1 GB/s
/// per link, 10 µs latency.
#[derive(Debug, Clone)]
pub struct SimNetConfig {
    pub latency_s: f64,
    pub bytes_per_s: f64,
}

impl SimNetConfig {
    /// Modeled seconds to move `bytes` point-to-point.
    pub fn transfer_secs(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bytes_per_s
    }
}

/// Session admission control: how the coordinator carves its worker pool
/// into per-session groups (the paper's `requestWorkers` negotiation;
/// multi-client serving as in Rothauge et al. 2019).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Sessions admitted concurrently; further handshakes queue FIFO.
    pub max_sessions: usize,
    /// Workers granted to a client that requests 0 ("server default");
    /// 0 here means the whole pool (single-tenant seed behavior).
    pub default_group_size: usize,
    /// Seconds a queued handshake waits for capacity before erroring.
    pub queue_timeout_s: f64,
    /// Tasks a session may hold *queued* (one more may be running);
    /// submissions beyond this are rejected with a clean error.
    pub task_queue_depth: usize,
    /// Matrix ids reserved per task for routine outputs; a routine
    /// returning more outputs fails cleanly instead of colliding with
    /// later ids (the v3 window was a fixed, unvalidated 64).
    pub max_task_outputs: u64,
    /// Milliseconds session teardown waits for a running task to observe
    /// its cooperative cancel token before escalating to a group poison
    /// (forcibly unwinding the routine at its next collective). 0
    /// disables the escalation — teardown then waits out the routine's
    /// remaining runtime, the pre-v5 behavior.
    pub teardown_grace_ms: u64,
    /// Highest admission priority class a handshake may claim (v9;
    /// classes run 0 = batch ..= 3 = urgent). Requests above it are
    /// clamped, not rejected; the clamped class is what admission and
    /// the metrics stream report.
    pub max_priority: u32,
    /// Starvation-freedom aging (v9): a queued handshake's effective
    /// class rises by one for every `age_secs` it has waited, so a
    /// steady stream of high-priority arrivals cannot park a batch
    /// session forever. 0 disables aging.
    pub age_secs: f64,
    /// Tasks one session may RUN concurrently (v9): the dispatcher gives
    /// each admitted task its own tag lane in the group communicator, so
    /// a pull can overlap a solve. Defaults to 1 — the pre-v9 serial
    /// dispatch — because concurrent tasks share the group's engine
    /// thread lease; raise it per deployment.
    pub tasks_per_group: usize,
    /// Default period of the push-based metrics stream in milliseconds
    /// (v9, `SubscribeMetrics`); a subscriber's explicit interval is
    /// clamped to no faster than 10 ms.
    pub metrics_interval_ms: u64,
    /// Weighted fair share across tenants (v9): within a priority class,
    /// the admission queue favors client names holding the fewest active
    /// sessions relative to their weight. `"name=weight"` pairs,
    /// comma-separated (`scheduler.weights = "spark=3,notebook=1"`);
    /// unlisted tenants weigh 1. Empty = plain FIFO within the class.
    pub weights: Vec<(String, f64)>,
    /// Standby worker ranks held out of the allocatable pool (v10,
    /// `docs/recovery.md`): when a rank dies mid-task the coordinator
    /// re-forms the group around a spare and restarts the task instead
    /// of failing the session. 0 (the default) disables replacement —
    /// a dead rank fails the session diagnosably, the pre-v10 behavior.
    pub spare_workers: usize,
    /// Seconds a session survives its client's TCP connection (v10):
    /// the task table and completed results are retained so a dropped
    /// client can `Reattach{token}` and collect them. 0 (the default)
    /// tears the session down on disconnect, the pre-v10 behavior —
    /// required for callers that treat dropping the socket as `stop()`.
    pub session_linger_s: f64,
}

impl SchedulerConfig {
    /// The fair-share weight configured for a tenant (by the client name
    /// it handshakes with); unlisted tenants weigh 1.
    pub fn tenant_weight(&self, client: &str) -> f64 {
        self.weights
            .iter()
            .find(|(n, _)| n == client)
            .map(|(_, w)| *w)
            .unwrap_or(1.0)
    }
}

/// Storage-plane budgets and spill behavior (`docs/storage.md`). The
/// tenant-isolation contract: one session's heap-resident matrix bytes
/// are bounded, overflow goes to a per-rank spill file instead of
/// growing the heap, and mmap-backed `LoadMatrix` blocks never count
/// (the kernel pages them against the file).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StorageConfig {
    /// Heap bytes one session may keep resident per worker rank
    /// (0 = unlimited). Enforced at `alloc`/`insert`: sealed cold blocks
    /// spill LRU-first to disk until the session fits; an ingest
    /// allocation that could never fit is rejected with a clean error
    /// (file-backed data belongs on the `LoadMatrix` path instead).
    pub budget_bytes: u64,
    /// Server-wide pool the per-session budgets are admitted against
    /// (0 = unlimited): a handshake is rejected when the sum of admitted
    /// sessions' `budget_bytes` would exceed this.
    pub total_bytes: u64,
    /// Directory for the per-rank spill files (empty = system temp dir).
    pub spill_dir: String,
    /// Directory for per-rank shard checkpoints of sealed blocks (v10,
    /// `docs/recovery.md`). Empty (the default) disables checkpointing;
    /// without it a dead rank's shards cannot be replayed onto a spare,
    /// so rank replacement degrades to the diagnosable failure. All
    /// ranks and the coordinator must see the same filesystem at this
    /// path (same host, or a shared mount).
    pub checkpoint_dir: String,
}

/// How a serve-mode coordinator runs its worker ranks (protocol v8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricMode {
    /// Ranks are threads in the server process over [`LocalComm`]
    /// mailboxes (the seed behavior).
    ///
    /// [`LocalComm`]: crate::collectives::LocalComm
    Local,
    /// Ranks are separate OS processes (`alchemist worker --connect`)
    /// joined by a coordinator-brokered TCP mesh
    /// ([`crate::collectives::TcpComm`], `docs/fabric.md`).
    Tcp,
}

impl FabricMode {
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "local" => FabricMode::Local,
            "tcp" => FabricMode::Tcp,
            other => bail!("unknown fabric mode {other:?} (local|tcp)"),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FabricMode::Local => "local",
            FabricMode::Tcp => "tcp",
        }
    }
}

/// Network rank-fabric transport tuning (protocol v8, `docs/fabric.md`).
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    /// Transport for serve-mode worker ranks.
    pub mode: FabricMode,
    /// Payloads at or above this stream through the gathered-write
    /// (`writev`) rendezvous path; smaller ones are buffered eagerly.
    pub eager_bytes: usize,
    /// Userspace buffer per mesh link.
    pub buf_bytes: usize,
    /// Seconds a rank waits for the full peer mesh to form.
    pub form_timeout_s: f64,
    /// Seconds the coordinator waits for spawned worker processes to
    /// attach before failing startup.
    pub attach_timeout_s: f64,
    /// Binary spawned as the worker process. Empty (the default) means
    /// the coordinator's own executable — correct for `alchemist serve`;
    /// test harnesses point this at the built `alchemist` binary since
    /// *their* executable is the test runner.
    pub worker_exe: String,
    /// Host (name or IP, no port — ports stay OS-assigned) a worker
    /// advertises for its mesh and data listeners instead of the
    /// loopback default (v10, `docs/fabric.md`). Empty (the default)
    /// binds and advertises `127.0.0.1`, the single-host behavior;
    /// setting a reachable hostname/IP binds `0.0.0.0` and advertises
    /// that name, the first step of the multi-host attach flow.
    pub advertise_addr: String,
}

impl FabricConfig {
    /// The transport-level options for [`crate::collectives::TcpComm`].
    pub fn options(&self) -> crate::collectives::FabricOptions {
        crate::collectives::FabricOptions {
            eager_bytes: self.eager_bytes,
            buf_bytes: self.buf_bytes,
            form_timeout: std::time::Duration::from_secs_f64(self.form_timeout_s),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Config {
    /// Master seed; all generator/jitter streams derive from it.
    pub seed: u64,
    pub engine: EngineKind,
    /// Intra-rank engine threadpool size (`engine.threads`; 0 = auto).
    /// Clamped per session at admission so `granted_workers × threads ≤
    /// available cores` — see [`Config::engine_threads_for_group`] and
    /// `docs/compute.md`. Results are bit-identical for any value (the
    /// native engine's determinism contract), so this is purely a
    /// throughput knob.
    pub engine_threads: usize,
    /// Directory with `manifest.txt` + `*.hlo.txt` from `make artifacts`.
    pub artifacts_dir: PathBuf,
    /// Square tile for composed GEMMs (must exist in the manifest).
    pub tile: usize,
    /// Row-panel height for gram/rff artifacts (must match manifest).
    pub panel_rows: usize,
    pub transfer: TransferConfig,
    pub overhead: OverheadConfig,
    pub simnet: SimNetConfig,
    pub scheduler: SchedulerConfig,
    pub storage: StorageConfig,
    pub fabric: FabricConfig,
    /// sparklite driver memory cap (bytes) — reproduces Table 1's "Spark
    /// cannot run >10k features" capability boundary.
    pub spark_driver_max_bytes: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            seed: 0xA1C4_E5D1,
            engine: EngineKind::Xla,
            engine_threads: 0,
            artifacts_dir: PathBuf::from("artifacts"),
            tile: 256,
            panel_rows: 2048,
            transfer: TransferConfig {
                rows_per_frame: 64,
                buf_bytes: 1 << 20,
                max_rows_per_frame: 4096,
                max_buf_bytes: 8 << 20,
                pull_stripe_rows: 1024,
                pull_window: 4,
                pull_window_bytes: 32 << 20,
            },
            overhead: OverheadConfig {
                scheduler_delay_s: 0.40,
                task_launch_s: 0.020,
                serde_bytes_per_s: 800e6,
                straggler_cv: 0.20,
            },
            simnet: SimNetConfig { latency_s: 10e-6, bytes_per_s: 1e9 },
            scheduler: SchedulerConfig {
                max_sessions: 8,
                default_group_size: 0,
                queue_timeout_s: 30.0,
                task_queue_depth: 16,
                max_task_outputs: 64,
                teardown_grace_ms: 2_000,
                max_priority: 3,
                age_secs: 10.0,
                tasks_per_group: 1,
                metrics_interval_ms: 250,
                weights: Vec::new(),
                spare_workers: 0,
                session_linger_s: 0.0,
            },
            storage: StorageConfig {
                budget_bytes: 0,
                total_bytes: 0,
                spill_dir: String::new(),
                checkpoint_dir: String::new(),
            },
            fabric: FabricConfig {
                mode: FabricMode::Local,
                eager_bytes: 4 << 10,
                buf_bytes: 1 << 20,
                form_timeout_s: 20.0,
                attach_timeout_s: 30.0,
                worker_exe: String::new(),
                advertise_addr: String::new(),
            },
            spark_driver_max_bytes: 192 << 20,
        }
    }
}

impl Config {
    /// Parse `key = value` lines (TOML-subset; see module docs).
    pub fn from_str_pairs(text: &str) -> crate::Result<BTreeMap<String, String>> {
        let mut out = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("config line {}: {raw:?}", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            out.insert(key, v.trim().trim_matches('"').to_string());
        }
        Ok(out)
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        let mut cfg = Config::default();
        cfg.apply_pairs(&Self::from_str_pairs(&text)?)?;
        Ok(cfg)
    }

    /// Apply `k=v` overrides (same keys as the file format).
    pub fn apply_pairs(
        &mut self,
        pairs: &BTreeMap<String, String>,
    ) -> crate::Result<()> {
        for (k, v) in pairs {
            self.apply(k, v)?;
        }
        Ok(())
    }

    pub fn apply(&mut self, key: &str, value: &str) -> crate::Result<()> {
        let fl = |v: &str| -> crate::Result<f64> {
            v.parse().with_context(|| format!("{key}: bad float {value:?}"))
        };
        let int = |v: &str| -> crate::Result<usize> {
            v.parse().with_context(|| format!("{key}: bad integer {value:?}"))
        };
        match key {
            "seed" => self.seed = value.parse().context("seed")?,
            "engine" => self.engine = EngineKind::parse(value)?,
            "engine.threads" => self.engine_threads = int(value)?,
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(value),
            "tile" => self.tile = int(value)?,
            "panel_rows" => self.panel_rows = int(value)?,
            "transfer.rows_per_frame" => self.transfer.rows_per_frame = int(value)?,
            "transfer.buf_bytes" => self.transfer.buf_bytes = int(value)?,
            "transfer.max_rows_per_frame" => {
                self.transfer.max_rows_per_frame = int(value)?
            }
            "transfer.max_buf_bytes" => self.transfer.max_buf_bytes = int(value)?,
            "transfer.pull_stripe_rows" => {
                self.transfer.pull_stripe_rows = int(value)?
            }
            "transfer.pull_window" => self.transfer.pull_window = int(value)?,
            "transfer.pull_window_bytes" => {
                self.transfer.pull_window_bytes = int(value)?
            }
            "overhead.scheduler_delay_s" => {
                self.overhead.scheduler_delay_s = fl(value)?
            }
            "overhead.task_launch_s" => self.overhead.task_launch_s = fl(value)?,
            "overhead.serde_bytes_per_s" => {
                self.overhead.serde_bytes_per_s = fl(value)?
            }
            "overhead.straggler_cv" => self.overhead.straggler_cv = fl(value)?,
            "simnet.latency_s" => self.simnet.latency_s = fl(value)?,
            "simnet.bytes_per_s" => self.simnet.bytes_per_s = fl(value)?,
            "scheduler.max_sessions" => self.scheduler.max_sessions = int(value)?,
            "scheduler.default_group_size" => {
                self.scheduler.default_group_size = int(value)?
            }
            "scheduler.queue_timeout_s" => {
                self.scheduler.queue_timeout_s = fl(value)?
            }
            "scheduler.task_queue_depth" => {
                self.scheduler.task_queue_depth = int(value)?
            }
            "scheduler.max_task_outputs" => {
                self.scheduler.max_task_outputs = int(value)? as u64
            }
            "scheduler.teardown_grace_ms" => {
                self.scheduler.teardown_grace_ms = int(value)? as u64
            }
            "scheduler.max_priority" => {
                self.scheduler.max_priority = int(value)? as u32
            }
            "scheduler.age_secs" => self.scheduler.age_secs = fl(value)?,
            "scheduler.tasks_per_group" => {
                self.scheduler.tasks_per_group = int(value)?.max(1)
            }
            "scheduler.metrics_interval_ms" => {
                self.scheduler.metrics_interval_ms = int(value)? as u64
            }
            "scheduler.weights" => {
                // "name=weight,name=weight" (note: comma-separated, so
                // this key cannot ride a worker's --set command line —
                // it is coordinator-side policy anyway)
                let mut weights = Vec::new();
                for pair in value.split(',').filter(|p| !p.trim().is_empty()) {
                    let (name, w) = pair.split_once('=').with_context(|| {
                        format!("scheduler.weights entry {pair:?}: want name=weight")
                    })?;
                    let w: f64 = w.trim().parse().with_context(|| {
                        format!("scheduler.weights entry {pair:?}: bad weight")
                    })?;
                    if w <= 0.0 {
                        bail!("scheduler.weights entry {pair:?}: weight must be > 0");
                    }
                    weights.push((name.trim().to_string(), w));
                }
                self.scheduler.weights = weights;
            }
            "scheduler.spare_workers" => {
                self.scheduler.spare_workers = int(value)?
            }
            "scheduler.session_linger_s" => {
                self.scheduler.session_linger_s = fl(value)?
            }
            "storage.budget_bytes" => {
                self.storage.budget_bytes = int(value)? as u64
            }
            "storage.total_bytes" => self.storage.total_bytes = int(value)? as u64,
            "storage.spill_dir" => self.storage.spill_dir = value.to_string(),
            "storage.checkpoint_dir" => {
                self.storage.checkpoint_dir = value.to_string()
            }
            "fabric.mode" => self.fabric.mode = FabricMode::parse(value)?,
            "fabric.worker_exe" => {
                self.fabric.worker_exe = value.to_string()
            }
            "fabric.advertise_addr" => {
                self.fabric.advertise_addr = value.to_string()
            }
            "fabric.eager_bytes" => self.fabric.eager_bytes = int(value)?,
            "fabric.buf_bytes" => self.fabric.buf_bytes = int(value)?,
            "fabric.form_timeout_s" => self.fabric.form_timeout_s = fl(value)?,
            "fabric.attach_timeout_s" => {
                self.fabric.attach_timeout_s = fl(value)?
            }
            "spark_driver_max_bytes" => {
                self.spark_driver_max_bytes = int(value)?
            }
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Effective per-rank engine threads for a session granted `group`
    /// workers on a machine with `avail` cores: `engine.threads`
    /// (0 = auto) clamped so `group × threads ≤ avail`, floored at 1.
    /// The session's worker ranks are themselves threads (`LocalComm`
    /// SPMD), so an unclamped pool would oversubscribe `group ×
    /// engine.threads` runnable threads onto `avail` cores and invert
    /// the intra-rank speedup.
    pub fn engine_threads_for_group(&self, group: usize, avail: usize) -> usize {
        let per_rank_cap = (avail / group.max(1)).max(1);
        match self.engine_threads {
            0 => per_rank_cap,
            t => t.min(per_rank_cap),
        }
    }

    /// The `k=v` override pairs a spawned worker process must inherit so
    /// its engines, store, and fabric agree with the coordinator's
    /// (passed as `--set` on the `alchemist worker` command line). Only
    /// worker-consumed keys are emitted, and values containing commas
    /// are skipped — `--set` splits its argument on commas, so such a
    /// value cannot ride the command line and the worker falls back to
    /// its compiled default.
    pub fn worker_override_pairs(&self) -> Vec<(String, String)> {
        let mut pairs: Vec<(String, String)> = vec![
            ("seed".into(), self.seed.to_string()),
            ("engine".into(), self.engine.as_str().into()),
            ("engine.threads".into(), self.engine_threads.to_string()),
            (
                "artifacts_dir".into(),
                self.resolved_artifacts_dir().display().to_string(),
            ),
            ("tile".into(), self.tile.to_string()),
            ("panel_rows".into(), self.panel_rows.to_string()),
            (
                "storage.budget_bytes".into(),
                self.storage.budget_bytes.to_string(),
            ),
            (
                "storage.total_bytes".into(),
                self.storage.total_bytes.to_string(),
            ),
            ("fabric.eager_bytes".into(), self.fabric.eager_bytes.to_string()),
            ("fabric.buf_bytes".into(), self.fabric.buf_bytes.to_string()),
            (
                "fabric.form_timeout_s".into(),
                self.fabric.form_timeout_s.to_string(),
            ),
        ];
        if !self.storage.spill_dir.is_empty() {
            pairs.push(("storage.spill_dir".into(), self.storage.spill_dir.clone()));
        }
        if !self.storage.checkpoint_dir.is_empty() {
            pairs.push((
                "storage.checkpoint_dir".into(),
                self.storage.checkpoint_dir.clone(),
            ));
        }
        if !self.fabric.advertise_addr.is_empty() {
            pairs.push((
                "fabric.advertise_addr".into(),
                self.fabric.advertise_addr.clone(),
            ));
        }
        pairs.retain(|(_, v)| !v.contains(','));
        pairs
    }

    /// Resolve the artifacts dir relative to the crate root when the
    /// default relative path does not exist from the current cwd (tests and
    /// benches run from various directories).
    pub fn resolved_artifacts_dir(&self) -> PathBuf {
        if self.artifacts_dir.exists() {
            return self.artifacts_dir.clone();
        }
        let from_manifest =
            Path::new(env!("CARGO_MANIFEST_DIR")).join(&self.artifacts_dir);
        if from_manifest.exists() {
            from_manifest
        } else {
            self.artifacts_dir.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert_eq!(c.engine, EngineKind::Xla);
        assert!(c.tile > 0 && c.panel_rows % c.tile == 0);
    }

    #[test]
    fn parse_toml_subset_with_sections() {
        let text = r#"
            # comment
            seed = 7
            engine = "pallas"

            [overhead]
            scheduler_delay_s = 1.5   # inline comment

            [transfer]
            rows_per_frame = 128

            [scheduler]
            max_sessions = 4
            default_group_size = 2
            queue_timeout_s = 1.25
            task_queue_depth = 3
            max_task_outputs = 8
        "#;
        let mut c = Config::default();
        c.apply_pairs(&Config::from_str_pairs(text).unwrap()).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.engine, EngineKind::Pallas);
        assert_eq!(c.overhead.scheduler_delay_s, 1.5);
        assert_eq!(c.transfer.rows_per_frame, 128);
        assert_eq!(c.scheduler.max_sessions, 4);
        assert_eq!(c.scheduler.default_group_size, 2);
        assert_eq!(c.scheduler.queue_timeout_s, 1.25);
        assert_eq!(c.scheduler.task_queue_depth, 3);
        assert_eq!(c.scheduler.max_task_outputs, 8);
    }

    #[test]
    fn scheduler_v9_keys_parse_and_default() {
        let c = Config::default();
        assert_eq!(c.scheduler.max_priority, 3);
        assert_eq!(c.scheduler.age_secs, 10.0);
        assert_eq!(c.scheduler.tasks_per_group, 1);
        assert_eq!(c.scheduler.metrics_interval_ms, 250);
        assert!(c.scheduler.weights.is_empty());
        assert_eq!(c.scheduler.tenant_weight("anyone"), 1.0);

        let mut c = Config::default();
        c.apply("scheduler.max_priority", "2").unwrap();
        c.apply("scheduler.age_secs", "0.5").unwrap();
        c.apply("scheduler.tasks_per_group", "4").unwrap();
        c.apply("scheduler.metrics_interval_ms", "50").unwrap();
        c.apply("scheduler.weights", "spark=3, notebook=1.5").unwrap();
        assert_eq!(c.scheduler.max_priority, 2);
        assert_eq!(c.scheduler.age_secs, 0.5);
        assert_eq!(c.scheduler.tasks_per_group, 4);
        assert_eq!(c.scheduler.metrics_interval_ms, 50);
        assert_eq!(c.scheduler.tenant_weight("spark"), 3.0);
        assert_eq!(c.scheduler.tenant_weight("notebook"), 1.5);
        assert_eq!(c.scheduler.tenant_weight("other"), 1.0);

        // tasks_per_group floors at 1 (0 would deadlock the dispatcher)
        c.apply("scheduler.tasks_per_group", "0").unwrap();
        assert_eq!(c.scheduler.tasks_per_group, 1);
        // malformed weights fail cleanly
        assert!(Config::default().apply("scheduler.weights", "spark").is_err());
        assert!(Config::default().apply("scheduler.weights", "spark=-1").is_err());
    }

    #[test]
    fn recovery_v10_keys_parse_and_default_off() {
        let c = Config::default();
        assert_eq!(c.scheduler.spare_workers, 0);
        assert_eq!(c.scheduler.session_linger_s, 0.0);
        assert!(c.storage.checkpoint_dir.is_empty());
        assert!(c.fabric.advertise_addr.is_empty());
        // defaults emit no extra worker overrides
        let keys: Vec<String> =
            c.worker_override_pairs().into_iter().map(|(k, _)| k).collect();
        assert!(!keys.iter().any(|k| k == "storage.checkpoint_dir"));
        assert!(!keys.iter().any(|k| k == "fabric.advertise_addr"));

        let mut c = Config::default();
        c.apply("scheduler.spare_workers", "2").unwrap();
        c.apply("scheduler.session_linger_s", "7.5").unwrap();
        c.apply("storage.checkpoint_dir", "/tmp/ckpt").unwrap();
        c.apply("fabric.advertise_addr", "10.0.0.7").unwrap();
        assert_eq!(c.scheduler.spare_workers, 2);
        assert_eq!(c.scheduler.session_linger_s, 7.5);
        assert_eq!(c.storage.checkpoint_dir, "/tmp/ckpt");
        assert_eq!(c.fabric.advertise_addr, "10.0.0.7");
        // worker-consumed keys ride the --set command line
        let mut w = Config::default();
        for (k, v) in c.worker_override_pairs() {
            w.apply(&k, &v).unwrap();
        }
        assert_eq!(w.storage.checkpoint_dir, "/tmp/ckpt");
        assert_eq!(w.fabric.advertise_addr, "10.0.0.7");
        // section form
        let text = "[scheduler]\nspare_workers = 1\nsession_linger_s = 3.0\n";
        let mut c2 = Config::default();
        c2.apply_pairs(&Config::from_str_pairs(text).unwrap()).unwrap();
        assert_eq!(c2.scheduler.spare_workers, 1);
        assert_eq!(c2.scheduler.session_linger_s, 3.0);
    }

    #[test]
    fn transfer_negotiation_clamps_to_limits() {
        let server = Config::default().transfer;
        // 0 means "server default"
        let eff = server.negotiate(0, 0);
        assert_eq!(eff.rows_per_frame, server.rows_per_frame);
        assert_eq!(eff.buf_bytes, server.buf_bytes);
        // in-range requests are honored
        let eff = server.negotiate(128, 1 << 16);
        assert_eq!(eff.rows_per_frame, 128);
        assert_eq!(eff.buf_bytes, 1 << 16);
        // out-of-range requests clamp to the server limits
        let eff = server.negotiate(1_000_000, 1 << 40);
        assert_eq!(eff.rows_per_frame, server.max_rows_per_frame);
        assert_eq!(eff.buf_bytes, server.max_buf_bytes);
        // tiny buffer floors at 4 KiB
        assert_eq!(server.negotiate(0, 1).buf_bytes, 4 << 10);
        // frame-granularity clamp for data connections
        assert_eq!(server.effective_frame_rows(0), server.rows_per_frame);
        assert_eq!(server.effective_frame_rows(7), 7);
        assert_eq!(
            server.effective_frame_rows(u32::MAX),
            server.max_rows_per_frame
        );
    }

    #[test]
    fn engine_threads_parse_and_group_clamp() {
        let mut c = Config::default();
        assert_eq!(c.engine_threads, 0);
        c.apply("engine.threads", "4").unwrap();
        assert_eq!(c.engine_threads, 4);
        // section form
        let text = "[engine]\nthreads = 2\n";
        let mut c2 = Config::default();
        c2.apply_pairs(&Config::from_str_pairs(text).unwrap()).unwrap();
        assert_eq!(c2.engine_threads, 2);

        // auto (0): whole per-rank share of the cores
        let auto = Config { engine_threads: 0, ..Config::default() };
        assert_eq!(auto.engine_threads_for_group(2, 8), 4);
        assert_eq!(auto.engine_threads_for_group(8, 8), 1);
        // more ranks than cores still floors at 1 thread
        assert_eq!(auto.engine_threads_for_group(16, 8), 1);
        assert_eq!(auto.engine_threads_for_group(0, 8), 8);

        // explicit values are honored up to the oversubscription clamp
        let four = Config { engine_threads: 4, ..Config::default() };
        assert_eq!(four.engine_threads_for_group(1, 8), 4);
        assert_eq!(four.engine_threads_for_group(4, 8), 2);
        assert_eq!(four.engine_threads_for_group(8, 8), 1);
    }

    #[test]
    fn engine_auto_parses_and_round_trips() {
        let mut c = Config::default();
        c.apply("engine", "auto").unwrap();
        assert_eq!(c.engine, EngineKind::Auto);
        assert_eq!(EngineKind::Auto.as_str(), "auto");
        assert_eq!(EngineKind::parse("auto").unwrap(), EngineKind::Auto);
    }

    #[test]
    fn storage_keys_parse_and_default_unlimited() {
        let c = Config::default();
        assert_eq!(c.storage.budget_bytes, 0);
        assert_eq!(c.storage.total_bytes, 0);
        assert!(c.storage.spill_dir.is_empty());
        let text = "[storage]\nbudget_bytes = 1048576\ntotal_bytes = 4194304\n\
                    spill_dir = \"/tmp/spill\"\n";
        let mut c = Config::default();
        c.apply_pairs(&Config::from_str_pairs(text).unwrap()).unwrap();
        assert_eq!(c.storage.budget_bytes, 1 << 20);
        assert_eq!(c.storage.total_bytes, 4 << 20);
        assert_eq!(c.storage.spill_dir, "/tmp/spill");
    }

    #[test]
    fn fabric_keys_parse_and_default_local() {
        let c = Config::default();
        assert_eq!(c.fabric.mode, FabricMode::Local);
        assert_eq!(c.fabric.eager_bytes, 4 << 10);
        let text = "[fabric]\nmode = \"tcp\"\neager_bytes = 512\n\
                    buf_bytes = 65536\nform_timeout_s = 5.5\n\
                    attach_timeout_s = 9.0\n";
        let mut c = Config::default();
        c.apply_pairs(&Config::from_str_pairs(text).unwrap()).unwrap();
        assert_eq!(c.fabric.mode, FabricMode::Tcp);
        assert_eq!(c.fabric.eager_bytes, 512);
        assert_eq!(c.fabric.buf_bytes, 1 << 16);
        assert_eq!(c.fabric.form_timeout_s, 5.5);
        assert_eq!(c.fabric.attach_timeout_s, 9.0);
        let opts = c.fabric.options();
        assert_eq!(opts.eager_bytes, 512);
        assert_eq!(opts.form_timeout, std::time::Duration::from_secs_f64(5.5));
        assert!(Config::default().apply("fabric.mode", "udp").is_err());
    }

    #[test]
    fn worker_override_pairs_round_trip() {
        let mut c = Config::default();
        c.apply("engine", "native").unwrap();
        c.apply("engine.threads", "2").unwrap();
        c.apply("fabric.eager_bytes", "128").unwrap();
        let mut w = Config::default();
        for (k, v) in c.worker_override_pairs() {
            assert!(!v.contains(','), "{k} value would split --set");
            w.apply(&k, &v).unwrap();
        }
        assert_eq!(w.engine, EngineKind::Native);
        assert_eq!(w.engine_threads, 2);
        assert_eq!(w.fabric.eager_bytes, 128);
        assert_eq!(w.seed, c.seed);
    }

    #[test]
    fn pull_window_bytes_parses() {
        let mut c = Config::default();
        assert_eq!(c.transfer.pull_window_bytes, 32 << 20);
        c.apply("transfer.pull_window_bytes", "1048576").unwrap();
        assert_eq!(c.transfer.pull_window_bytes, 1 << 20);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = Config::default();
        assert!(c.apply("does_not_exist", "1").is_err());
        assert!(c.apply("engine", "gpu").is_err());
    }

    #[test]
    fn simnet_transfer_model_monotone() {
        let s = SimNetConfig { latency_s: 1e-5, bytes_per_s: 1e9 };
        assert!(s.transfer_secs(1 << 20) > s.transfer_secs(1 << 10));
        assert!(s.transfer_secs(0) == 1e-5);
    }
}
