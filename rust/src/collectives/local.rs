//! In-process communicator: ranks are threads, messages are mailboxes.
//!
//! Used by the coordinator's worker group (the paper runs Alchemist's MPI
//! ranks inside one allocation; we run them inside one process). A
//! [`crate::config::SimNetConfig`] cost model charges each *received*
//! message with modeled interconnect time so the SimClock can reconstruct
//! what the same traffic would cost across nodes.
//!
//! The fabric is poison-aware (protocol v5 fault isolation): `poison`
//! stamps the shared state and wakes every rank blocked in a mailbox wait
//! or in the barrier, so a dead rank's peers unwind with a
//! [`CommError`] instead of blocking forever. Because one fabric serves a
//! session across many tasks, the driver calls [`LocalComm::reset`]
//! between tasks to clear the poison and drain undelivered messages.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::SimNetConfig;

use super::{lane_of_tag, CommError, Communicator, Fabric, PoisonCause};

type Key = (usize, u64); // (sender, tag)

#[derive(Default)]
struct Mailbox {
    // FIFO per (sender, tag)
    queues: Mutex<HashMap<Key, std::collections::VecDeque<Vec<f64>>>>,
    signal: Condvar,
}

/// Condvar barrier (std's [`std::sync::Barrier`] cannot be woken early,
/// which is exactly what poisoning needs to do).
#[derive(Default)]
struct BarrierState {
    arrived: usize,
    generation: u64,
}

struct Shared {
    boxes: Vec<Mailbox>,
    barrier: Mutex<BarrierState>,
    barrier_cv: Condvar,
    /// First poison wins: the recorded cause is the root cause.
    poison: Mutex<Option<PoisonCause>>,
    /// Lock-free fast-path mirror of `poison.is_some()`: every receive
    /// attempt and barrier pass checks for poison, and in steady state
    /// (never poisoned) all ranks would otherwise contend on the one
    /// fabric-global poison mutex from inside their mailbox/barrier
    /// critical sections. Set (Release) after the cause is recorded;
    /// cleared by `reset`.
    poison_flag: AtomicBool,
    /// Per-lane poison (protocol v9): hard-cancelling ONE task poisons
    /// only its tag lane, so a sibling task's traffic on this same fabric
    /// keeps flowing. Group-wide poison (above) still overrides every
    /// lane — a dead rank fails all tasks on the group.
    lane_poison: Mutex<HashMap<u64, PoisonCause>>,
    /// Mirror of `lane_poison.is_empty()` (same fast-path idiom as
    /// `poison_flag`: the steady state must not take the map's mutex on
    /// every receive attempt).
    lane_poison_flag: AtomicBool,
    simnet: Option<SimNetConfig>,
}

impl Shared {
    fn poisoned(&self) -> Option<PoisonCause> {
        if !self.poison_flag.load(Ordering::Acquire) {
            return None;
        }
        *self.poison.lock().unwrap()
    }

    /// The poison governing `lane`: group-wide first (root cause), then
    /// the lane's own.
    fn lane_poisoned(&self, lane: u64) -> Option<PoisonCause> {
        if let Some(cause) = self.poisoned() {
            return Some(cause);
        }
        if !self.lane_poison_flag.load(Ordering::Acquire) {
            return None;
        }
        self.lane_poison.lock().unwrap().get(&lane).copied()
    }
}

/// One rank's endpoint into the shared in-proc fabric.
pub struct LocalComm {
    rank: usize,
    size: usize,
    /// This endpoint's rank in the server's full worker pool (== `rank`
    /// for groups built with [`LocalComm::group`]).
    global_rank: usize,
    shared: Arc<Shared>,
    /// Modeled comm nanoseconds charged to this rank.
    sim_ns: Arc<AtomicU64>,
}

impl LocalComm {
    /// Create endpoints for a `size`-rank group.
    pub fn group(size: usize, simnet: Option<SimNetConfig>) -> Vec<LocalComm> {
        assert!(size > 0);
        let ranks: Vec<usize> = (0..size).collect();
        Self::subgroup(&ranks, simnet)
    }

    /// Create endpoints for an independent communicator over an arbitrary
    /// subset of global worker ranks (session-scoped worker groups).
    /// Endpoint `i` gets group-local rank `i` and remembers
    /// `global_ranks[i]`. The fabric (mailboxes, barrier) is fresh, so
    /// collectives on disjoint subgroups never contend with each other.
    pub fn subgroup(
        global_ranks: &[usize],
        simnet: Option<SimNetConfig>,
    ) -> Vec<LocalComm> {
        let size = global_ranks.len();
        assert!(size > 0, "subgroup must have at least one rank");
        {
            let mut sorted = global_ranks.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), size, "subgroup ranks must be distinct");
        }
        let shared = Arc::new(Shared {
            boxes: (0..size).map(|_| Mailbox::default()).collect(),
            barrier: Mutex::new(BarrierState::default()),
            barrier_cv: Condvar::new(),
            poison: Mutex::new(None),
            poison_flag: AtomicBool::new(false),
            lane_poison: Mutex::new(HashMap::new()),
            lane_poison_flag: AtomicBool::new(false),
            simnet,
        });
        global_ranks
            .iter()
            .enumerate()
            .map(|(rank, &global_rank)| LocalComm {
                rank,
                size,
                global_rank,
                shared: shared.clone(),
                sim_ns: Arc::new(AtomicU64::new(0)),
            })
            .collect()
    }

    /// Rank in the server's full worker pool (group-local ranks are what
    /// [`Communicator::rank`] returns).
    pub fn global_rank(&self) -> usize {
        self.global_rank
    }

    /// Driver-side reset between tasks on the same group: clear the
    /// poison, drain every undelivered message (a failed task may have
    /// left sends its dead peer never received — the next task must not
    /// read them as its own traffic), and zero the barrier arrival count.
    ///
    /// Callers must guarantee no rank of the group is inside a collective
    /// (the dispatcher calls this only after every rank's task reply has
    /// been gathered).
    pub fn reset(&self) {
        // cause first, flag second: a racing reader that still sees the
        // flag set falls through to the mutex and reads the cleared
        // cause — i.e. observes "not poisoned", never a stale cause
        *self.shared.poison.lock().unwrap() = None;
        self.shared.poison_flag.store(false, Ordering::Release);
        self.shared.lane_poison.lock().unwrap().clear();
        self.shared.lane_poison_flag.store(false, Ordering::Release);
        for mbox in &self.shared.boxes {
            mbox.queues.lock().unwrap().clear();
        }
        self.shared.barrier.lock().unwrap().arrived = 0;
    }

    /// Retire one task's tag lane (protocol v9): drop its queued
    /// messages on every mailbox and clear its lane poison. Delivery is
    /// synchronous (a send lands in the mailbox before the sender's call
    /// returns), so once every rank of the task has replied there is
    /// nothing in flight — draining the queues is complete.
    pub fn retire_lane(&self, lane: u64) {
        for mbox in &self.shared.boxes {
            mbox.queues
                .lock()
                .unwrap()
                .retain(|&(_, tag), _| lane_of_tag(tag) != lane);
        }
        let mut lanes = self.shared.lane_poison.lock().unwrap();
        lanes.remove(&lane);
        if lanes.is_empty() {
            self.shared.lane_poison_flag.store(false, Ordering::Release);
        }
    }

    fn charge(&self, bytes: usize) {
        if let Some(net) = &self.shared.simnet {
            let secs = net.transfer_secs(bytes);
            self.sim_ns
                .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        }
    }

    /// Shared receive loop: block until a matching message, the poison,
    /// or (when `deadline` is set) the deadline — whichever comes first.
    /// Poison wins over an available message so unwinding is prompt and
    /// deterministic once the group has failed.
    fn recv_inner(
        &self,
        from: usize,
        tag: u64,
        deadline: Option<Instant>,
    ) -> Result<Vec<f64>, CommError> {
        let lane = lane_of_tag(tag);
        let mbox = &self.shared.boxes[self.rank];
        let mut queues = mbox.queues.lock().unwrap();
        loop {
            // checked while holding the queue lock: `poison` (group-wide
            // and per-lane) notifies under this lock, so a waiter can
            // never miss the wakeup
            if let Some(cause) = self.shared.lane_poisoned(lane) {
                return Err(cause.to_err());
            }
            if let Some(q) = queues.get_mut(&(from, tag)) {
                if let Some(data) = q.pop_front() {
                    drop(queues);
                    self.charge(data.len() * 8);
                    return Ok(data);
                }
            }
            match deadline {
                None => queues = mbox.signal.wait(queues).unwrap(),
                Some(deadline) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(CommError::Timeout { from, tag });
                    }
                    let (guard, _) =
                        mbox.signal.wait_timeout(queues, deadline - now).unwrap();
                    queues = guard;
                }
            }
        }
    }
}

impl Communicator for LocalComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&self, to: usize, tag: u64, data: Vec<f64>) {
        debug_assert!(to < self.size);
        let mbox = &self.shared.boxes[to];
        let mut queues = mbox.queues.lock().unwrap();
        queues.entry((self.rank, tag)).or_default().push_back(data);
        mbox.signal.notify_all();
    }

    fn recv(&self, from: usize, tag: u64) -> Result<Vec<f64>, CommError> {
        self.recv_inner(from, tag, None)
    }

    fn recv_deadline(
        &self,
        from: usize,
        tag: u64,
        timeout: Duration,
    ) -> Result<Vec<f64>, CommError> {
        self.recv_inner(from, tag, Some(Instant::now() + timeout))
    }

    fn barrier(&self) -> Result<(), CommError> {
        let shared = &self.shared;
        let mut st = shared.barrier.lock().unwrap();
        if let Some(cause) = shared.poisoned() {
            return Err(cause.to_err());
        }
        st.arrived += 1;
        if st.arrived == self.size {
            st.arrived = 0;
            st.generation = st.generation.wrapping_add(1);
            shared.barrier_cv.notify_all();
            return Ok(());
        }
        let generation = st.generation;
        loop {
            st = shared.barrier_cv.wait(st).unwrap();
            if st.generation != generation {
                return Ok(());
            }
            if let Some(cause) = shared.poisoned() {
                // departing with an error: undo our arrival so the count
                // stays consistent (moot while poisoned — every call
                // errors up front — but `reset` relies on it)
                st.arrived -= 1;
                return Err(cause.to_err());
            }
        }
    }

    fn poison(&self, cause: PoisonCause) {
        {
            let mut p = self.shared.poison.lock().unwrap();
            if p.is_none() {
                *p = Some(cause);
            }
            // flag set AFTER the cause, inside the critical section: any
            // reader that observes the flag finds the cause recorded
            self.shared.poison_flag.store(true, Ordering::Release);
        }
        // wake every rank blocked in a mailbox wait; notifying under the
        // queue lock makes the wakeup race-free against a waiter that
        // just checked the poison and is about to wait
        for mbox in &self.shared.boxes {
            let _guard = mbox.queues.lock().unwrap();
            mbox.signal.notify_all();
        }
        // and everyone parked in the barrier
        let _guard = self.shared.barrier.lock().unwrap();
        self.shared.barrier_cv.notify_all();
    }

    fn poison_cause(&self) -> Option<PoisonCause> {
        self.shared.poisoned()
    }

    fn poison_lane(&self, lane: u64, cause: PoisonCause) {
        {
            let mut lanes = self.shared.lane_poison.lock().unwrap();
            lanes.entry(lane).or_insert(cause);
            // flag set AFTER the cause, inside the critical section (the
            // same publication order as the group-wide flag)
            self.shared.lane_poison_flag.store(true, Ordering::Release);
        }
        // wake every rank blocked in a mailbox wait — receivers on other
        // lanes re-check and go back to sleep; the poisoned lane's error
        // out. The group barrier is untouched: lane barriers ride recv.
        for mbox in &self.shared.boxes {
            let _guard = mbox.queues.lock().unwrap();
            mbox.signal.notify_all();
        }
    }

    fn lane_poison_cause(&self, lane: u64) -> Option<PoisonCause> {
        self.shared.lane_poisoned(lane)
    }

    fn sim_comm_secs(&self) -> f64 {
        self.sim_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

impl Fabric for LocalComm {
    fn reset(&self) {
        LocalComm::reset(self)
    }

    fn retire_lane(&self, lane: u64) {
        LocalComm::retire_lane(self, lane)
    }

    fn as_comm(&self) -> &dyn Communicator {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_ranks<F>(n: usize, f: F)
    where
        F: Fn(LocalComm) + Send + Sync + Clone + 'static,
    {
        let comms = LocalComm::group(n, None);
        let mut handles = Vec::new();
        for c in comms {
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(c)));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn point_to_point_fifo_per_tag() {
        spawn_ranks(2, |c| {
            if c.rank() == 0 {
                c.send(1, 5, vec![1.0]);
                c.send(1, 5, vec![2.0]);
                c.send(1, 9, vec![3.0]);
            } else {
                // tag 9 can be read before tag 5's backlog
                assert_eq!(c.recv(0, 9).unwrap(), vec![3.0]);
                assert_eq!(c.recv(0, 5).unwrap(), vec![1.0]);
                assert_eq!(c.recv(0, 5).unwrap(), vec![2.0]);
            }
        });
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        COUNT.store(0, Ordering::SeqCst);
        spawn_ranks(4, |c| {
            COUNT.fetch_add(1, Ordering::SeqCst);
            c.barrier().unwrap();
            // after the barrier every rank must observe all 4 arrivals
            assert_eq!(COUNT.load(Ordering::SeqCst), 4);
        });
    }

    #[test]
    fn barrier_reusable_across_generations() {
        spawn_ranks(3, |c| {
            for _ in 0..50 {
                c.barrier().unwrap();
            }
        });
    }

    #[test]
    fn subgroup_is_local_ranked_and_independent() {
        // two disjoint subgroups of a 5-rank pool run collectives
        // concurrently without seeing each other's traffic or barriers
        let ga = [1usize, 4];
        let gb = [0usize, 2, 3];
        let ca = LocalComm::subgroup(&ga, None);
        let cb = LocalComm::subgroup(&gb, None);
        for (i, c) in ca.iter().enumerate() {
            assert_eq!(c.rank(), i);
            assert_eq!(c.size(), 2);
            assert_eq!(c.global_rank(), ga[i]);
        }
        let mut handles = Vec::new();
        for c in ca.into_iter().chain(cb.into_iter()) {
            handles.push(std::thread::spawn(move || {
                // ring exchange within the group, then a group barrier:
                // would deadlock if the fabrics were shared
                let next = (c.rank() + 1) % c.size();
                let prev = (c.rank() + c.size() - 1) % c.size();
                c.send(next, 7, vec![c.global_rank() as f64]);
                let got = c.recv(prev, 7).unwrap();
                assert_eq!(got.len(), 1);
                c.barrier().unwrap();
                got[0]
            }));
        }
        let vals: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let mut sorted = vals;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(sorted, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "distinct")]
    fn subgroup_rejects_duplicate_ranks() {
        let _ = LocalComm::subgroup(&[1, 1], None);
    }

    #[test]
    fn sim_cost_charged_on_receive() {
        let comms = LocalComm::group(
            2,
            Some(crate::config::SimNetConfig { latency_s: 1e-6, bytes_per_s: 1e9 }),
        );
        let [c0, c1]: [LocalComm; 2] = comms.try_into().map_err(|_| ()).unwrap();
        let t = std::thread::spawn(move || {
            c0.send(1, 0, vec![0.0; 1000]);
            c0.sim_comm_secs()
        });
        let _ = c1.recv(0, 0).unwrap();
        let sender_cost = t.join().unwrap();
        assert_eq!(sender_cost, 0.0);
        // 8000 bytes at 1 GB/s + 1 µs = 9 µs
        assert!((c1.sim_comm_secs() - 9e-6).abs() < 1e-7, "{}", c1.sim_comm_secs());
    }

    #[test]
    fn recv_deadline_times_out_without_poisoning() {
        let comms = LocalComm::group(2, None);
        let err = comms[0]
            .recv_deadline(1, 3, Duration::from_millis(20))
            .unwrap_err();
        assert_eq!(err, CommError::Timeout { from: 1, tag: 3 });
        assert_eq!(comms[0].poison_cause(), None);
        // a message that arrives in time is still delivered
        comms[1].send(0, 3, vec![8.0]);
        assert_eq!(
            comms[0].recv_deadline(1, 3, Duration::from_secs(5)).unwrap(),
            vec![8.0]
        );
    }

    #[test]
    fn poison_wakes_blocked_recv_and_barrier() {
        let mut comms = LocalComm::group(3, None);
        let dead = comms.pop().unwrap(); // rank 2 "dies" without collecting
        let mut handles = Vec::new();
        for c in comms {
            handles.push(std::thread::spawn(move || {
                if c.rank() == 0 {
                    c.recv(2, 1).unwrap_err()
                } else {
                    c.barrier().unwrap_err()
                }
            }));
        }
        // let both block, then poison (what rank 2's worker loop does)
        std::thread::sleep(Duration::from_millis(50));
        dead.poison(PoisonCause::RankFailed(2));
        for h in handles {
            assert_eq!(h.join().unwrap(), CommError::PeerFailed { rank: 2 });
        }
    }

    #[test]
    fn first_poison_cause_wins() {
        let comms = LocalComm::group(2, None);
        comms[0].poison(PoisonCause::RankFailed(0));
        comms[1].poison(PoisonCause::HardCancel);
        assert_eq!(comms[0].poison_cause(), Some(PoisonCause::RankFailed(0)));
        assert_eq!(
            comms[1].recv(0, 0).unwrap_err(),
            CommError::PeerFailed { rank: 0 }
        );
    }

    #[test]
    fn reset_clears_poison_and_drains_stale_messages() {
        let comms = LocalComm::group(2, None);
        // a failed "task" leaves an undelivered message and a poison
        comms[0].send(1, 9, vec![1.0]);
        comms[0].poison(PoisonCause::RankFailed(0));
        assert!(comms[1].recv(0, 9).is_err());
        comms[1].reset();
        assert_eq!(comms[0].poison_cause(), None);
        // the stale message is gone: a deadline recv times out
        assert_eq!(
            comms[1].recv_deadline(0, 9, Duration::from_millis(20)),
            Err(CommError::Timeout { from: 0, tag: 9 })
        );
        // and the fabric is fully usable again
        comms[0].send(1, 9, vec![2.0]);
        assert_eq!(comms[1].recv(0, 9).unwrap(), vec![2.0]);
    }

    #[test]
    fn lane_poison_spares_sibling_lane() {
        use super::super::lane_base;
        let comms = LocalComm::group(2, None);
        // lane 1 poisoned; lane 2's traffic keeps flowing
        comms[0].poison_lane(1, PoisonCause::HardCancel);
        assert_eq!(
            comms[1].recv(0, lane_base(1) + 7).unwrap_err(),
            CommError::Cancelled
        );
        comms[0].send(1, lane_base(2) + 7, vec![3.0]);
        assert_eq!(comms[1].recv(0, lane_base(2) + 7).unwrap(), vec![3.0]);
        assert_eq!(comms[0].poison_cause(), None, "group-wide poison untouched");
        assert_eq!(comms[0].lane_poison_cause(2), None);
        // retiring the lane clears its poison and drops its stragglers
        comms[0].send(1, lane_base(1) + 8, vec![9.0]);
        comms[1].retire_lane(1);
        assert_eq!(comms[0].lane_poison_cause(1), None);
        assert_eq!(
            comms[1].recv_deadline(0, lane_base(1) + 8, Duration::from_millis(20)),
            Err(CommError::Timeout { from: 0, tag: lane_base(1) + 8 })
        );
    }

    #[test]
    fn lane_poison_wakes_blocked_lane_recv() {
        let mut comms = LocalComm::group(2, None);
        let driver = comms.pop().unwrap();
        let waiter = comms.pop().unwrap();
        let h = std::thread::spawn(move || {
            waiter.recv(1, super::super::lane_base(3) + 1).unwrap_err()
        });
        std::thread::sleep(Duration::from_millis(50));
        driver.poison_lane(3, PoisonCause::HardCancel);
        assert_eq!(h.join().unwrap(), CommError::Cancelled);
    }

    #[test]
    fn lane_comm_offsets_tags_and_runs_collectives() {
        use super::super::{allreduce_sum, Fabric, LaneComm};
        let comms = LocalComm::group(3, None);
        let mut handles = Vec::new();
        for c in comms {
            handles.push(std::thread::spawn(move || {
                let lane = LaneComm::new(Arc::new(c) as Arc<dyn Fabric>, 5);
                let mut v = vec![(lane.rank() + 1) as f64];
                allreduce_sum(&lane, 0x5500_0000, &mut v).unwrap();
                lane.barrier().unwrap();
                v[0]
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 6.0);
        }
    }
}
