//! Integration: the asynchronous task subsystem (protocol v4) — submit /
//! status / cancel / wait lifecycle, cooperative mid-task cancellation,
//! bounded task queues, rank-tagged failures, output-id reservations, and
//! teardown that never leaks store blocks.

use std::time::{Duration, Instant};

use alchemist::client::AlchemistContext;
use alchemist::config::{Config, EngineKind};
use alchemist::coordinator::AlchemistServer;
use alchemist::protocol::{Params, TaskState};

fn native_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.engine = EngineKind::Native;
    cfg
}

/// Poll until `f` returns true or the timeout fires (sleep-based tests
/// stay robust on slow CI runners).
fn eventually(timeout: Duration, what: &str, mut f: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !f() {
        assert!(t0.elapsed() < timeout, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn submit_poll_cancel_lifecycle() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // a long-running routine: 30s of cancellable 10ms slices
    let task_id = ac
        .submit("elemental", "sleep", Params::new().with_i64("millis", 30_000))
        .unwrap()
        .task_id;

    // poll while Running: progress must become nonzero and carry the
    // group size
    let t0 = Instant::now();
    loop {
        assert!(t0.elapsed() < Duration::from_secs(10), "never saw progress");
        match ac.task(task_id).status().unwrap() {
            TaskState::Queued => {}
            TaskState::Running { progress } => {
                assert_eq!(progress.ranks, 2);
                if progress.iters > 0 {
                    break;
                }
            }
            other => panic!("unexpected state {other:?}"),
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // cancel mid-task: the token is observed cooperatively within a
    // slice, long before the 30s sleep elapses
    let t_cancel = Instant::now();
    ac.task(task_id).cancel().unwrap();
    let err = ac.task(task_id).wait().unwrap_err();
    assert!(err.to_string().contains("cancelled"), "{err}");
    assert!(
        t_cancel.elapsed() < Duration::from_secs(5),
        "cancel took {:?} — not cooperative",
        t_cancel.elapsed()
    );
    // terminal state is sticky and cancel stays idempotent
    assert_eq!(ac.task(task_id).status().unwrap(), TaskState::Cancelled);
    assert_eq!(ac.task(task_id).cancel().unwrap(), TaskState::Cancelled);

    // the session is left usable: a synchronous task runs fine after
    let res = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 20))
        .unwrap();
    assert_eq!(res.scalars.i64("ranks").unwrap(), 2);

    let m = server.sched_metrics();
    assert_eq!(m.tasks_submitted, 2);
    assert_eq!(m.tasks_cancelled, 1);
    assert_eq!(m.tasks_done, 1);
    assert_eq!(m.queued_tasks, 0);
    assert_eq!(m.running_tasks, 0);
    assert_eq!(m.wait_count, 2, "both tasks were dispatched");

    ac.stop();
    server.shutdown();
}

#[test]
fn queue_bounds_cancel_while_queued_and_wait_timeout() {
    let mut cfg = native_cfg();
    cfg.apply("scheduler.task_queue_depth", "1").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 1).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // first task occupies the group...
    let running = ac
        .submit("elemental", "sleep", Params::new().with_i64("millis", 30_000))
        .unwrap()
        .task_id;
    eventually(Duration::from_secs(10), "task to start", || {
        matches!(ac.task(running).status().unwrap(), TaskState::Running { .. })
    });
    // ...so a WaitTask with a short timeout comes back non-terminal
    let st = ac.task(running).wait_timeout(50).unwrap();
    assert!(matches!(st, TaskState::Running { .. }), "{st:?}");

    // second task queues; the third submission hits the depth-1 bound
    let queued = ac
        .submit("elemental", "sleep", Params::new().with_i64("millis", 30_000))
        .unwrap()
        .task_id;
    assert_eq!(ac.task(queued).status().unwrap(), TaskState::Queued);
    // the backlog is attributable to this tenant, not just a global count
    let depths = server.session_queue_depths();
    assert_eq!(depths.len(), 1);
    assert_eq!(depths[0].queued, 1);
    assert_eq!(depths[0].running, 1);
    let err = ac
        .submit("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap_err();
    assert!(err.to_string().contains("task queue full"), "{err}");

    // cancel while Queued is immediate — the task never ran
    assert_eq!(ac.task(queued).cancel().unwrap(), TaskState::Cancelled);

    // queue slot freed: a new submission is accepted again, and the whole
    // pipeline drains once the running task is cancelled
    let follow = ac
        .submit("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap()
        .task_id;
    ac.task(running).cancel().unwrap();
    assert!(ac.task(running).wait().is_err());
    let st = ac.task(follow).wait_timeout(10_000).unwrap();
    assert!(matches!(st, TaskState::Done { .. }), "{st:?}");

    let m = server.sched_metrics();
    assert_eq!(m.tasks_rejected, 1);
    assert_eq!(m.tasks_cancelled, 2);
    assert_eq!(m.tasks_done, 1);
    // the follow-up task waited behind a running one: nonzero wait shows
    // up in the backpressure distribution
    assert!(m.wait_count >= 2);
    assert!(m.wait_max_s > 0.0, "queued wait time was not recorded");

    ac.stop();
    server.shutdown();
}

#[test]
fn one_rank_failure_is_distinguishable_from_group_failure() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // one rank wedges: the error names the rank and the 1-of-2 count
    let err = ac
        .run_task("elemental", "fail_on", Params::new().with_i64("rank", 1))
        .unwrap_err();
    assert!(err.to_string().contains("1 of 2 ranks failed"), "{err}");
    assert!(err.to_string().contains("rank 1"), "{err}");

    // a group-wide failure (unknown routine fails everywhere) reads
    // differently
    let err = ac.run_task("elemental", "nope", Params::new()).unwrap_err();
    assert!(err.to_string().contains("2 of 2 ranks failed"), "{err}");

    // the full per-rank detail is on the wire too
    let task_id = ac
        .submit("elemental", "fail_on", Params::new().with_i64("rank", 0))
        .unwrap()
        .task_id;
    let st = ac.task(task_id).wait_timeout(10_000).unwrap();
    match st {
        TaskState::Failed { failed_ranks, total_ranks, message } => {
            assert_eq!(failed_ranks, vec![0]);
            assert_eq!(total_ranks, 2);
            assert!(message.contains("injected"), "{message}");
        }
        other => panic!("unexpected state {other:?}"),
    }

    // the session survives all of the above
    let res = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap();
    assert_eq!(res.scalars.i64("ranks").unwrap(), 2);
    ac.stop();
    server.shutdown();
}

#[test]
fn output_reservation_rejects_oversized_routines_without_id_collision() {
    let mut cfg = native_cfg();
    // truncated_svd returns U, S, V — three outputs against a window of 2
    cfg.apply("scheduler.max_task_outputs", "2").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    let a = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 24).with_i64("cols", 6).with_i64("seed", 3),
        )
        .unwrap();
    let a_id = a.outputs[0].id;

    let err = ac
        .run_task(
            "elemental",
            "truncated_svd",
            Params::new().with_matrix("A", a_id).with_i64("rank", 2),
        )
        .unwrap_err();
    assert!(err.to_string().contains("reservation"), "{err}");

    // nothing from the failed task leaked into the store (only A's two
    // rank-blocks remain) and later ids don't collide with its window
    eventually(Duration::from_secs(5), "failed task's blocks to be freed", || {
        server.total_blocks() == 2
    });
    let b = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 8).with_i64("cols", 2).with_i64("seed", 4),
        )
        .unwrap();
    assert_ne!(b.outputs[0].id, a_id);
    let (back, _) = ac.to_indexed_row_matrix(&b.outputs[0], 1).unwrap();
    assert_eq!(back.rows, 8);

    ac.stop();
    server.shutdown();
}

#[test]
fn disconnect_with_task_in_flight_cancels_and_frees_everything() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    // a running 30s task plus queued work, then the client vanishes
    {
        let mut ac = AlchemistContext::connect(&addr, &cfg, 1).unwrap();
        ac.register_library("elemental", "builtin:elemental").unwrap();
        let running = ac
            .submit("elemental", "sleep", Params::new().with_i64("millis", 30_000))
            .unwrap()
            .task_id;
        eventually(Duration::from_secs(10), "task to start", || {
            matches!(ac.task(running).status().unwrap(), TaskState::Running { .. })
        });
        for _ in 0..3 {
            ac.submit("elemental", "sleep", Params::new().with_i64("millis", 30_000))
                .unwrap();
        }
        ac.stop();
    }
    // teardown cancels the running task cooperatively and drains the
    // queue — well before any 30s sleep could finish
    let t0 = Instant::now();
    eventually(Duration::from_secs(10), "session teardown", || {
        server.active_sessions() == 0
    });
    assert!(t0.elapsed() < Duration::from_secs(10));

    // a task that *produces outputs* racing teardown must not leak
    // blocks: the dispatcher is joined before the store is freed
    {
        let mut ac = AlchemistContext::connect(&addr, &cfg, 1).unwrap();
        ac.register_library("elemental", "builtin:elemental").unwrap();
        ac.submit(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 64).with_i64("cols", 8).with_i64("seed", 5),
        )
        .unwrap();
        ac.stop(); // disconnect immediately, task possibly mid-flight
    }
    eventually(Duration::from_secs(10), "blocks to be freed", || {
        server.active_sessions() == 0 && server.total_blocks() == 0
    });

    // the workers were actually released: a fresh session can take the
    // whole pool and run
    let mut ac = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 2).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();
    let res = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap();
    assert_eq!(res.scalars.i64("ranks").unwrap(), 2);
    ac.stop();
    server.shutdown();
}

#[test]
fn iterative_cg_cancels_mid_iteration_over_the_wire() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2).unwrap();
    ac.register_library("skylark", "builtin:skylark").unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // server-side problem big enough to iterate visibly: an unconvergeable
    // solve (tol is effectively zero) capped far beyond test time
    let x = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 512).with_i64("cols", 128).with_i64("seed", 1),
        )
        .unwrap();
    let y = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 512).with_i64("cols", 4).with_i64("seed", 2),
        )
        .unwrap();
    let task_id = ac
        .submit(
            "skylark",
            "cg_solve",
            Params::new()
                .with_matrix("X", x.outputs[0].id)
                .with_matrix("Y", y.outputs[0].id)
                .with_f64("tol", 0.0)
                .with_i64("max_iters", 500_000_000),
        )
        .unwrap()
        .task_id;

    // CG reports (iteration, residual) as it runs
    let t0 = Instant::now();
    loop {
        assert!(t0.elapsed() < Duration::from_secs(20), "never saw CG progress");
        if let TaskState::Running { progress } = ac.task(task_id).status().unwrap() {
            if progress.iters >= 2 && progress.residual >= 0.0 {
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // the cancel is observed within an iteration — both ranks bail
    // together through the collective check, nobody hangs in an allreduce
    let t_cancel = Instant::now();
    ac.task(task_id).cancel().unwrap();
    let err = ac.task(task_id).wait().unwrap_err();
    assert!(err.to_string().contains("cancelled"), "{err}");
    assert!(t_cancel.elapsed() < Duration::from_secs(10));

    // group still healthy: another CG converges normally
    let res = ac
        .run_task(
            "skylark",
            "cg_solve",
            Params::new()
                .with_matrix("X", x.outputs[0].id)
                .with_matrix("Y", y.outputs[0].id)
                .with_i64("max_iters", 200),
        )
        .unwrap();
    assert!(res.scalars.i64("iters").unwrap() > 0);
    ac.stop();
    server.shutdown();
}

#[test]
fn stranded_rank_panic_propagates_and_names_root_cause() {
    // THE protocol-v5 scenario: rank 1 panics while its two peers are
    // blocked in an allreduce it never joins. Pre-v5 the peers hung
    // forever (and teardown with them); now the poison releases them,
    // the task fails promptly, and the client sees rank 1 as the one
    // root cause — not the peers' collateral unwinding.
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 3).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    let t0 = Instant::now();
    let task_id = ac
        .submit(
            "elemental",
            "fail_on",
            Params::new()
                .with_i64("rank", 1)
                .with_i64("panic", 1)
                .with_i64("strand", 1),
        )
        .unwrap()
        .task_id;
    let st = ac.task(task_id).wait_timeout(20_000).unwrap();
    match st {
        TaskState::Failed { message, failed_ranks, total_ranks } => {
            assert_eq!(failed_ranks, vec![1], "root cause only, not collateral");
            assert_eq!(total_ranks, 3);
            assert!(message.contains("1 of 3 ranks failed"), "{message}");
            assert!(message.contains("rank 1"), "{message}");
            assert!(message.contains("panicked"), "{message}");
            assert!(message.contains("aborted after the failure"), "{message}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(15),
        "failure took {:?} — peers were stranded",
        t0.elapsed()
    );

    // nothing leaked and the group is healthy again: the reserved output
    // window was freed and a follow-up task runs on the same fabric
    eventually(Duration::from_secs(5), "failed task's blocks to be freed", || {
        server.total_blocks() == 0
    });
    let res = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap();
    assert_eq!(res.scalars.i64("ranks").unwrap(), 3);

    ac.stop();
    server.shutdown();
}

#[test]
fn rank_error_between_collectives_fails_cleanly_not_hangs() {
    // same shape but with an error instead of a panic, on a 2-rank group
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    let t0 = Instant::now();
    let err = ac
        .run_task(
            "elemental",
            "fail_on",
            Params::new().with_i64("rank", 0).with_i64("strand", 1),
        )
        .unwrap_err();
    assert!(err.to_string().contains("1 of 2 ranks failed"), "{err}");
    assert!(err.to_string().contains("rank 0"), "{err}");
    assert!(t0.elapsed() < Duration::from_secs(15), "peer was stranded");

    ac.stop();
    server.shutdown();
}

#[test]
fn hard_cancel_unwinds_routine_that_ignores_cooperative_cancellation() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // `spin` deliberately never observes the cooperative token: 30s of
    // barrier-synchronized slices only a hard cancel can end early
    let task_id = ac
        .submit("elemental", "spin", Params::new().with_i64("millis", 30_000))
        .unwrap()
        .task_id;
    eventually(Duration::from_secs(10), "spin to start", || {
        matches!(
            ac.task(task_id).status().unwrap(),
            TaskState::Running { progress } if progress.iters > 0
        )
    });

    // escalate: cooperative request + 200ms grace, then the group is
    // poisoned and the next barrier unwinds every rank
    let t_cancel = Instant::now();
    ac.task(task_id).cancel_hard(200).unwrap();
    let err = ac.task(task_id).wait().unwrap_err();
    assert!(err.to_string().contains("cancelled"), "{err}");
    assert!(
        t_cancel.elapsed() < Duration::from_secs(10),
        "hard cancel took {:?} — deadline + one collective was exceeded",
        t_cancel.elapsed()
    );

    // the audit trail: the task landed in a terminal Cancelled state
    // (never a stuck Running), its reserved output-id window was freed,
    // and the fabric was reset so the session keeps working
    assert_eq!(ac.task(task_id).status().unwrap(), TaskState::Cancelled);
    assert_eq!(server.total_blocks(), 0);
    let res = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap();
    assert_eq!(res.scalars.i64("ranks").unwrap(), 2);

    let m = server.sched_metrics();
    assert_eq!(m.tasks_cancelled, 1);
    assert_eq!(m.tasks_done, 1);
    assert_eq!(m.running_tasks, 0);

    ac.stop();
    server.shutdown();
}

#[test]
fn engine_checkins_cancel_collective_free_kernel_loop() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    // `burn` is the pre-v6 blind spot: it never polls the cooperative
    // token AND never enters a collective, so neither the token nor group
    // poison has anywhere to land — only the engine-level kernel
    // check-ins can end it. The worker installs the task's token into the
    // engine, whose GEMM observes it at an MC-panel boundary and bails.
    let task_id = ac
        .submit("elemental", "burn", Params::new().with_i64("millis", 30_000))
        .unwrap()
        .task_id;
    eventually(Duration::from_secs(10), "burn to start", || {
        matches!(ac.task(task_id).status().unwrap(), TaskState::Running { .. })
    });

    let t_cancel = Instant::now();
    ac.task(task_id).cancel_hard(200).unwrap();
    let err = ac.task(task_id).wait().unwrap_err();
    assert!(err.to_string().contains("cancelled"), "{err}");
    assert!(
        t_cancel.elapsed() < Duration::from_secs(10),
        "cancel took {:?} — the engine kernel check-ins never fired",
        t_cancel.elapsed()
    );

    // terminal Cancelled (not Failed), nothing leaked, group healthy
    assert_eq!(ac.task(task_id).status().unwrap(), TaskState::Cancelled);
    assert_eq!(server.total_blocks(), 0);
    let res = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap();
    assert_eq!(res.scalars.i64("ranks").unwrap(), 2);

    ac.stop();
    server.shutdown();
}

#[test]
fn teardown_escalates_past_uncooperative_routine() {
    // a disconnecting client leaves an uncooperative `spin` running: the
    // teardown grace must bound how long the session lingers (pre-v5 the
    // dispatcher join waited out the routine's full remaining runtime)
    let mut cfg = native_cfg();
    cfg.apply("scheduler.teardown_grace_ms", "200").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    {
        let mut ac = AlchemistContext::connect(&addr, &cfg, 1).unwrap();
        ac.register_library("elemental", "builtin:elemental").unwrap();
        let task_id = ac
            .submit("elemental", "spin", Params::new().with_i64("millis", 30_000))
            .unwrap()
            .task_id;
        eventually(Duration::from_secs(10), "spin to start", || {
            matches!(ac.task(task_id).status().unwrap(), TaskState::Running { .. })
        });
        ac.stop(); // vanish with the spin still running
    }
    let t0 = Instant::now();
    eventually(Duration::from_secs(10), "session teardown", || {
        server.active_sessions() == 0
    });
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "teardown took {:?} — the escalation never fired",
        t0.elapsed()
    );
    assert_eq!(server.total_blocks(), 0);

    // the pool is genuinely free again
    let mut ac = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 2).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();
    let res = ac
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 10))
        .unwrap();
    assert_eq!(res.scalars.i64("ranks").unwrap(), 2);
    ac.stop();
    server.shutdown();
}
