//! Table 6 (this repo's addition): storage-plane throughput — what the
//! out-of-core plane buys on the ingest and egress legs.
//!
//! Four ways the same ocean field can enter/leave the server:
//!
//! * `load_push`    — classic v3 push: client reads the file, streams
//!   every payload byte over TCP (`send_matrix`).
//! * `load_direct`  — v7 `LoadMatrix`: each worker maps its shard of the
//!   file; zero payload bytes cross the client link. The paper's "let
//!   Alchemist read the file" use case, now a first-class RPC.
//! * `pull_heap`    — pull a heap-resident (pushed) block.
//! * `pull_mapped`  — pull a mapped (direct-loaded) block: the worker
//!   serves frames straight out of the file mapping, zero-copy.
//! * `pull_spilled` — pull a block the budget forced to the spill file:
//!   frames stream through a bounded buffer straight off disk.
//!
//! Emits `BENCH_storage.json` with `--json PATH`; the committed
//! `BENCH_storage.json` stub in the repo root is the baseline CI diffs
//! against (`scripts/check_bench_baseline.py`, kind "storage", which
//! also enforces the direct >= 2x push ingest expectation).

mod bench_common;

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::coordinator::AlchemistServer;
use alchemist::metrics::{Stats, Table};
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::util::fmt;
use alchemist::workloads::OceanSpec;
use bench_common::{bench_config, is_quick};

struct Cell {
    case: &'static str,
    secs: f64,
    gbps: f64,
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

fn write_json(
    path: &str,
    rows: usize,
    cols: usize,
    runs: usize,
    quick: bool,
    workers: usize,
    cells: &[Cell],
) -> alchemist::Result<()> {
    let mut body = String::new();
    body.push_str("{\n");
    body.push_str("  \"bench\": \"table6_storage\",\n");
    body.push_str("  \"kind\": \"storage\",\n");
    body.push_str(&format!(
        "  \"protocol_version\": {},\n",
        alchemist::protocol::PROTOCOL_VERSION
    ));
    body.push_str(
        "  \"units\": {\"secs\": \"mean wallclock seconds\", \"gbps\": \"GB/s, 1e9 bytes\"},\n",
    );
    body.push_str(&format!(
        "  \"config\": {{\"rows\": {rows}, \"cols\": {cols}, \"runs\": {runs}, \
         \"quick\": {quick}, \"workers\": {workers}}},\n"
    ));
    body.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"case\": \"{}\", \"secs\": {}, \"gbps\": {}}}{}\n",
            c.case,
            json_num(c.secs),
            json_num(c.gbps),
            if i + 1 == cells.len() { "" } else { "," },
        ));
    }
    body.push_str("  ]\n}\n");
    std::fs::write(path, body)?;
    println!("wrote {path}");
    Ok(())
}

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let mut cfg = bench_config(&args)?;
    cfg.apply("engine", "native")?; // storage plane only; engine never runs
    let quick = is_quick(&args);
    let rows = args.get_usize("rows", if quick { 8_192 } else { 65_536 })?;
    let cols = args.get_usize("cols", if quick { 512 } else { 1_024 })?;
    let workers = args.get_usize("workers", 3)?;
    let runs = args.get_usize("runs", 3)?;
    let bytes = (rows * cols * 8) as u64;

    let dir = std::env::temp_dir().join("alchemist-bench-storage");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("ocean_{rows}x{cols}.bin"));
    let spec = OceanSpec { cells: rows, times: cols, ..OceanSpec::default() };
    if !path.exists() {
        let t0 = std::time::Instant::now();
        spec.write_file(&path)?;
        println!(
            "wrote {} dataset in {:.2}s",
            fmt::bytes(bytes),
            t0.elapsed().as_secs_f64()
        );
    }

    let mut load_push = Stats::new();
    let mut load_direct = Stats::new();
    let mut pull_heap = Stats::new();
    let mut pull_mapped = Stats::new();
    let mut pull_spilled = Stats::new();

    // ---- heap/mapped legs: one unlimited-budget server ----
    {
        let server = AlchemistServer::start(cfg.clone(), workers)?;
        for run in 0..runs {
            let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, workers)?;
            // push leg reads the file client-side, then ships every byte
            let local = alchemist::hdf5sim::read_matrix(&path)?;
            let irm = IndexedRowMatrix::from_local(&local, workers * 2);
            let (al_push, s) = ac.send_matrix(&format!("push{run}"), &irm)?;
            load_push.push(s.secs);

            let t0 = std::time::Instant::now();
            let (al_map, s) = ac.load_matrix(&format!("map{run}"), path.to_str().unwrap())?;
            let direct_secs = t0.elapsed().as_secs_f64();
            anyhow::ensure!(
                s.bytes == 0,
                "direct load moved {} payload bytes over the client link",
                s.bytes
            );
            load_direct.push(direct_secs);

            let (back, p) = ac.to_indexed_row_matrix(&al_push, workers)?;
            anyhow::ensure!(back.rows == rows && back.cols == cols);
            pull_heap.push(p.secs);
            let (back, p) = ac.to_indexed_row_matrix(&al_map, workers)?;
            anyhow::ensure!(back.rows == rows && back.cols == cols);
            pull_mapped.push(p.secs);

            ac.free(&al_push)?;
            ac.free(&al_map)?;
            ac.stop();
        }
        let snap = server.storage_metrics();
        anyhow::ensure!(
            snap.blocks_mapped as usize >= workers * runs,
            "direct loads registered {} mapped blocks, expected >= {}",
            snap.blocks_mapped,
            workers * runs
        );
        server.shutdown();
    }

    // ---- spilled leg: budget fits ~1.6 of the 3 pushed blocks, so the
    // oldest gets evicted to the spill file; pulling it streams frames
    // straight off disk ----
    {
        let per_rank = bytes / workers as u64;
        let mut cfg2 = cfg.clone();
        cfg2.storage.budget_bytes = per_rank + per_rank * 3 / 5;
        let server = AlchemistServer::start(cfg2.clone(), workers)?;
        for run in 0..runs {
            let mut ac = AlchemistContext::connect(&server.control_addr, &cfg2, workers)?;
            let local = alchemist::hdf5sim::read_matrix(&path)?;
            let irm = IndexedRowMatrix::from_local(&local, workers * 2);
            let (al_a, _) = ac.send_matrix(&format!("a{run}"), &irm)?;
            let (al_b, _) = ac.send_matrix(&format!("b{run}"), &irm)?;
            // inserting B blew the budget, so A (LRU) is on disk now
            let (back, p) = ac.to_indexed_row_matrix(&al_a, workers)?;
            anyhow::ensure!(back.rows == rows && back.cols == cols);
            pull_spilled.push(p.secs);
            ac.free(&al_a)?;
            ac.free(&al_b)?;
            ac.stop();
        }
        let snap = server.storage_metrics();
        anyhow::ensure!(
            snap.cycled(),
            "spill leg never cycled blocks through the spill file: {snap:?}"
        );
        server.shutdown();
    }

    let gb = bytes as f64 / 1e9;
    let cells: Vec<Cell> = [
        ("load_push", load_push),
        ("load_direct", load_direct),
        ("pull_heap", pull_heap),
        ("pull_mapped", pull_mapped),
        ("pull_spilled", pull_spilled),
    ]
    .into_iter()
    .map(|(case, s)| Cell { case, secs: s.mean(), gbps: gb / s.mean() })
    .collect();

    let mut table = Table::new(
        "Table 6: storage-plane throughput (mean of runs)",
        &["case", "secs", "GB/s"],
    );
    for c in &cells {
        table.row(&[c.case.into(), format!("{:.3}", c.secs), format!("{:.2}", c.gbps)]);
    }
    table.print();
    println!(
        "(direct load maps the file server-side — its advantage over push grows \
         with the dataset; spilled pulls are bounded-memory streams off disk)"
    );

    if let Some(path) = args.get("json") {
        write_json(path, rows, cols, runs, quick, workers, &cells)?;
    }
    Ok(())
}
