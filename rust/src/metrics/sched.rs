//! Scheduler backpressure metrics (ROADMAP "admission priorities +
//! backpressure metrics", the metrics half): live gauges for the
//! admission queue and the per-session task queues, counters over task
//! outcomes, and the Queued→Running wait-time distribution.
//!
//! The driver holds one [`SchedMetrics`]; every update is a lock-free
//! atomic except the wait-time [`Stats`] (one short mutex per task
//! start). [`SchedMetrics::snapshot`] is the read side —
//! `ServerHandle::sched_metrics()` exposes it to operators and tests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::Stats;

/// Counters and gauges the coordinator's admission and task paths feed.
#[derive(Debug, Default)]
pub struct SchedMetrics {
    /// Handshakes currently waiting in the admission queue.
    admission_queue_depth: AtomicU64,
    /// Tasks currently queued (all sessions; per-session depth is bounded
    /// by `scheduler.task_queue_depth`).
    queued_tasks: AtomicU64,
    /// Tasks currently running (≤ one per session group).
    running_tasks: AtomicU64,
    tasks_submitted: AtomicU64,
    tasks_done: AtomicU64,
    tasks_failed: AtomicU64,
    tasks_cancelled: AtomicU64,
    /// Submissions rejected because the session's queue was full.
    tasks_rejected: AtomicU64,
    /// Seconds from submission to dispatch (the backpressure signal).
    queued_wait: Mutex<Stats>,
}

/// Point-in-time copy of every metric (plain data, safe to hold).
#[derive(Debug, Clone, Default)]
pub struct SchedSnapshot {
    pub admission_queue_depth: u64,
    pub queued_tasks: u64,
    pub running_tasks: u64,
    pub tasks_submitted: u64,
    pub tasks_done: u64,
    pub tasks_failed: u64,
    pub tasks_cancelled: u64,
    pub tasks_rejected: u64,
    pub wait_count: u64,
    pub wait_mean_s: f64,
    pub wait_max_s: f64,
}

/// How a task left the table (feeds the outcome counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOutcome {
    Done,
    Failed,
    Cancelled,
}

/// One live session's task backlog (reported by
/// `ServerHandle::session_queue_depths`): the global `queued_tasks`
/// gauge says how much work is waiting overall, this says *whose* — a
/// tenant pinned at its `scheduler.task_queue_depth` bound looks very
/// different from light load spread across sessions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SessionQueueDepth {
    pub session_id: u64,
    /// Tasks waiting in this session's FIFO.
    pub queued: usize,
    /// Whether a task is currently executing on the session's group.
    pub running: bool,
}

impl SchedMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn admission_enqueued(&self) {
        self.admission_queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    pub fn admission_dequeued(&self) {
        self.admission_queue_depth.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn task_submitted(&self) {
        self.tasks_submitted.fetch_add(1, Ordering::Relaxed);
        self.queued_tasks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn task_rejected(&self) {
        self.tasks_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// A task left the queue for a worker group; `wait_secs` is its
    /// Queued→Running latency.
    pub fn task_started(&self, wait_secs: f64) {
        self.queued_tasks.fetch_sub(1, Ordering::Relaxed);
        self.running_tasks.fetch_add(1, Ordering::Relaxed);
        self.queued_wait.lock().unwrap().push(wait_secs);
    }

    /// A *running* task reached a terminal state.
    pub fn task_finished(&self, outcome: TaskOutcome) {
        self.running_tasks.fetch_sub(1, Ordering::Relaxed);
        self.count_outcome(outcome);
    }

    /// A *queued* task reached a terminal state without running
    /// (cancelled while queued, or drained at session teardown).
    pub fn task_dequeued(&self, outcome: TaskOutcome) {
        self.queued_tasks.fetch_sub(1, Ordering::Relaxed);
        self.count_outcome(outcome);
    }

    fn count_outcome(&self, outcome: TaskOutcome) {
        let c = match outcome {
            TaskOutcome::Done => &self.tasks_done,
            TaskOutcome::Failed => &self.tasks_failed,
            TaskOutcome::Cancelled => &self.tasks_cancelled,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> SchedSnapshot {
        let wait = self.queued_wait.lock().unwrap().clone();
        SchedSnapshot {
            admission_queue_depth: self.admission_queue_depth.load(Ordering::Relaxed),
            queued_tasks: self.queued_tasks.load(Ordering::Relaxed),
            running_tasks: self.running_tasks.load(Ordering::Relaxed),
            tasks_submitted: self.tasks_submitted.load(Ordering::Relaxed),
            tasks_done: self.tasks_done.load(Ordering::Relaxed),
            tasks_failed: self.tasks_failed.load(Ordering::Relaxed),
            tasks_cancelled: self.tasks_cancelled.load(Ordering::Relaxed),
            tasks_rejected: self.tasks_rejected.load(Ordering::Relaxed),
            wait_count: wait.count(),
            wait_mean_s: if wait.count() > 0 { wait.mean() } else { 0.0 },
            wait_max_s: if wait.count() > 0 { wait.max() } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_counts_balance() {
        let m = SchedMetrics::new();
        m.admission_enqueued();
        assert_eq!(m.snapshot().admission_queue_depth, 1);
        m.admission_dequeued();

        // one task runs to completion, one is cancelled while queued,
        // one submission is rejected
        m.task_submitted();
        m.task_submitted();
        m.task_rejected();
        m.task_started(0.25);
        m.task_finished(TaskOutcome::Done);
        m.task_dequeued(TaskOutcome::Cancelled);

        let s = m.snapshot();
        assert_eq!(s.admission_queue_depth, 0);
        assert_eq!(s.queued_tasks, 0);
        assert_eq!(s.running_tasks, 0);
        assert_eq!(s.tasks_submitted, 2);
        assert_eq!(s.tasks_done, 1);
        assert_eq!(s.tasks_cancelled, 1);
        assert_eq!(s.tasks_rejected, 1);
        assert_eq!(s.wait_count, 1);
        assert!((s.wait_mean_s - 0.25).abs() < 1e-12);
        assert_eq!(s.wait_max_s, 0.25);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = SchedMetrics::new().snapshot();
        assert_eq!(s.wait_count, 0);
        assert_eq!(s.wait_mean_s, 0.0);
        assert_eq!(s.wait_max_s, 0.0);
    }
}
