//! The libSkylark stand-in (paper §4.1): conjugate gradient on the
//! regularized normal equations, plus server-side random-feature
//! expansion.
//!
//! Routines:
//!
//! * `rff_expand(X, d, gamma, seed)` → `Z` — expand raw features to `d`
//!   random Fourier features (the paper ships the small 440-column matrix
//!   and expands inside Alchemist; shipping the expanded TBs would swamp
//!   the transfer path).
//! * `cg_solve(X, Y, lambda, tol, max_iters [, rff_d, rff_gamma,
//!   rff_seed])` → `W` — block CG; with `rff_d > 0` the feature matrix is
//!   expanded first and the expansion time reported separately (Table 2's
//!   columns).

use crate::linalg::cg::{cg_solve_scoped, CgOptions};
use crate::linalg::rff::RffMap;
use crate::protocol::{Params, Value};
use crate::util::timer::Stopwatch;

use super::super::registry::{Library, OutputMatrix, TaskOutput, WorkerCtx};
use super::distribute_replicated;

pub struct Skylark;

impl Library for Skylark {
    fn name(&self) -> &'static str {
        "skylark"
    }

    fn routines(&self) -> Vec<&'static str> {
        vec!["rff_expand", "cg_solve"]
    }

    fn run(
        &self,
        routine: &str,
        params: &Params,
        ctx: &mut WorkerCtx,
    ) -> crate::Result<TaskOutput> {
        match routine {
            "rff_expand" => rff_expand(params, ctx),
            "cg_solve" => cg_solve_routine(params, ctx),
            other => anyhow::bail!("skylark has no routine {other:?}"),
        }
    }
}

fn rff_expand(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let x_id = params.matrix("X")?;
    let d = params.i64("d")? as usize;
    let gamma = params.f64_or("gamma", 1.0)?;
    let seed = params.i64_or("seed", 1)? as u64;

    let (layout, x_local) = ctx.local_block(x_id)?;
    let map = RffMap::generate(x_local.cols(), d, gamma, seed);

    let mut sw = Stopwatch::new();
    sw.start("expand");
    let z_local = map.expand(ctx.engine, &x_local)?;
    sw.stop();

    let mut z_layout = layout.clone();
    z_layout.cols = d;
    Ok(TaskOutput {
        matrices: vec![OutputMatrix {
            name: "Z".into(),
            layout: z_layout,
            local: z_local,
        }],
        scalars: Params::new().with_i64("d", d as i64),
        timings: vec![("expand".into(), sw.secs("expand"))],
    })
}

fn cg_solve_routine(params: &Params, ctx: &mut WorkerCtx) -> crate::Result<TaskOutput> {
    let x_id = params.matrix("X")?;
    let y_id = params.matrix("Y")?;
    let opts = CgOptions {
        lambda: params.f64_or("lambda", 1e-5)?,
        tol: params.f64_or("tol", 1e-8)?,
        max_iters: params.i64_or("max_iters", 500)? as usize,
    };
    let rff_d = params.i64_or("rff_d", 0)? as usize;

    let (x_layout, mut x_local) = ctx.local_block(x_id)?;
    let (y_layout, y_local) = ctx.local_block(y_id)?;
    anyhow::ensure!(
        x_layout.ranges == y_layout.ranges,
        "X and Y must share their row distribution"
    );

    let mut sw = Stopwatch::new();
    if rff_d > 0 {
        // expand in place, like the paper: raw features in, CG on the
        // expanded matrix, expanded data never crosses the network
        let gamma = params.f64_or("rff_gamma", 1.0)?;
        let seed = params.i64_or("rff_seed", 1)? as u64;
        let map = RffMap::generate(x_local.cols(), rff_d, gamma, seed);
        sw.start("expand");
        x_local = map.expand(ctx.engine, &x_local)?;
        sw.stop();
    }

    sw.start("compute");
    // under the task scope: per-iteration progress (iteration, residual)
    // and cooperative cancellation within one iteration
    let res = cg_solve_scoped(
        ctx.comm,
        ctx.engine,
        &x_local,
        &y_local,
        x_layout.rows,
        &opts,
        ctx.scope,
    )?;
    sw.stop();

    let (w_layout, w_local) =
        distribute_replicated(&res.w, ctx.comm.size(), ctx.rank);
    let scalars = Params::new()
        .with_i64("iters", res.iters as i64)
        .with_f64(
            "final_residual",
            res.residuals.last().copied().unwrap_or(0.0),
        )
        .set("iter_secs", Value::F64s(res.iter_secs.clone()))
        .set("residuals", Value::F64s(res.residuals.clone()));
    Ok(TaskOutput {
        matrices: vec![OutputMatrix {
            name: "W".into(),
            layout: w_layout,
            local: w_local,
        }],
        scalars,
        timings: vec![
            ("expand".into(), sw.secs("expand")),
            ("compute".into(), sw.secs("compute")),
        ],
    })
}
