//! End-to-end driver (paper §4.1): speech classification by ridge
//! regression with random features, Spark-baseline vs Alchemist-offload.
//!
//! This is the full-system validation run recorded in EXPERIMENTS.md:
//! synthetic TIMIT corpus → raw features shipped over TCP → server-side
//! random-feature expansion → block CG to tolerance (residual curve
//! logged) → weights pulled back → train/test accuracy evaluated against
//! the sparklite baseline running the same mathematics.
//!
//! ```sh
//! cargo run --release --example speech_cg -- \
//!     [--rows 16384] [--rff-d 1024] [--workers 3] [--executors 3] \
//!     [--engine xla] [--max-iters 60] [--skip-spark]
//! ```

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::config::Config;
use alchemist::coordinator::AlchemistServer;
use alchemist::distmat::LocalMatrix;
use alchemist::linalg::{CgOptions, RffMap};
use alchemist::metrics::Table;
use alchemist::protocol::{Params, Value};
use alchemist::sparklite::{mllib, IndexedRowMatrix, SparkEngine};
use alchemist::util::fmt;
use alchemist::workloads::{timit, TimitSpec};

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let mut cfg = Config::default();
    if let Some(engine) = args.get("engine") {
        cfg.apply("engine", engine)?;
    }
    let rows = args.get_usize("rows", 16_384)?;
    let rff_d = args.get_usize("rff-d", 1024)?;
    let workers = args.get_usize("workers", 3)?;
    let executors = args.get_usize("executors", 3)?;
    let max_iters = args.get_usize("max-iters", 60)?;
    let lambda = args.get_f64("lambda", 1e-5)?;
    let spec_probe = TimitSpec::default();
    let gamma = args.get_f64("gamma", spec_probe.default_gamma())?;
    let skip_spark = args.flag("skip-spark");

    // ---- corpus ----
    let spec = TimitSpec { train_rows: rows, test_rows: rows / 8, ..TimitSpec::default() };
    println!(
        "generating synthetic TIMIT: {} train rows x {} raw features, {} classes",
        spec.train_rows, spec.raw_features, spec.classes
    );
    let data = spec.generate();
    let x_irm = IndexedRowMatrix::from_local(&data.x_train, workers * 2);
    let y_irm = IndexedRowMatrix::from_local(&data.y_train, workers * 2);

    let rff_seed: i64 = 0x5EED;
    let map = RffMap::generate(spec.raw_features, rff_d, gamma, rff_seed as u64);
    let opts = CgOptions { lambda, tol: 1e-6, max_iters };

    let mut table = Table::new(
        "speech_cg: Spark baseline vs Alchemist offload",
        &[
            "system", "iters", "per-iter (s)", "per-iter sim (s)", "total (s)",
            "transfer (s)", "train acc", "test acc",
        ],
    );

    // evaluation helper: accuracy of W on train/test via the same map
    let eval = |w: &LocalMatrix| -> alchemist::Result<(f64, f64)> {
        let mut ne = alchemist::compute::NativeEngine::new();
        let z_tr = map.expand(&mut ne, &data.x_train)?;
        let mut s_tr = LocalMatrix::zeros(z_tr.rows(), spec.classes);
        s_tr.gemm_nn(&z_tr, w);
        let z_te = map.expand(&mut ne, &data.x_test)?;
        let mut s_te = LocalMatrix::zeros(z_te.rows(), spec.classes);
        s_te.gemm_nn(&z_te, w);
        Ok((
            timit::accuracy(&s_tr, &data.labels_train),
            timit::accuracy(&s_te, &data.labels_test),
        ))
    };

    // ---- Spark baseline ----
    if !skip_spark {
        println!("\n== sparklite baseline: expand + CG under the BSP overhead model ==");
        let mut engine = SparkEngine::new(workers, &cfg);
        let t0 = std::time::Instant::now();
        let z = mllib::rff_expand(&mut engine, &x_irm, &map)?;
        let res = mllib::cg_solve(&mut engine, &z, &y_irm, &opts)?;
        let total = t0.elapsed().as_secs_f64();
        let per: alchemist::metrics::Stats = res.iter_secs.iter().copied().collect();
        let per_sim: alchemist::metrics::Stats =
            res.iter_sim_secs.iter().copied().collect();
        println!("residual curve (spark): {:?}", curve(&res.residuals));
        let (tr, te) = eval(&res.w)?;
        table.row(&[
            "spark".into(),
            res.iters.to_string(),
            per.mean_pm_std(3),
            per_sim.mean_pm_std(3),
            format!("{total:.2}"),
            "n/a".into(),
            format!("{tr:.3}"),
            format!("{te:.3}"),
        ]);
    }

    // ---- Alchemist offload ----
    println!("\n== alchemist offload: raw features over TCP, expand + CG server-side ==");
    let server = AlchemistServer::start(cfg.clone(), workers)?;
    let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, executors)?;
    ac.register_library("skylark", "builtin:skylark")?;

    let t0 = std::time::Instant::now();
    let (al_x, sx) = ac.send_matrix("X", &x_irm)?;
    let (al_y, sy) = ac.send_matrix("Y", &y_irm)?;
    println!(
        "transfer: X {} in {:.3}s ({:.2} GB/s), Y {} in {:.3}s",
        fmt::bytes(al_x.size_bytes() as u64),
        sx.secs,
        sx.throughput_gbps(),
        fmt::bytes(al_y.size_bytes() as u64),
        sy.secs,
    );

    let res = ac.run_task(
        "skylark",
        "cg_solve",
        Params::new()
            .with_matrix("X", al_x.id)
            .with_matrix("Y", al_y.id)
            .with_f64("lambda", lambda)
            .with_f64("tol", opts.tol)
            .with_i64("max_iters", max_iters as i64)
            .with_i64("rff_d", rff_d as i64)
            .with_f64("rff_gamma", gamma)
            .with_i64("rff_seed", rff_seed),
    )?;
    let al_w = res.output("W")?.clone();
    let (w_irm, sw) = ac.to_indexed_row_matrix(&al_w, 1)?;
    let total = t0.elapsed().as_secs_f64();
    let w = w_irm.to_local()?;

    let iters = res.scalars.i64("iters")? as usize;
    let iter_secs = match res.scalars.get("iter_secs") {
        Some(Value::F64s(v)) => v.clone(),
        _ => vec![],
    };
    let residuals = match res.scalars.get("residuals") {
        Some(Value::F64s(v)) => v.clone(),
        _ => vec![],
    };
    println!("residual curve (alchemist): {:?}", curve(&residuals));
    println!(
        "server timings: expand {:.3}s, compute {:.3}s, sim {:.3}s; W pulled in {:.3}s",
        res.timing("expand"),
        res.timing("compute"),
        res.timing("sim_secs"),
        sw.secs
    );
    let per: alchemist::metrics::Stats = iter_secs.iter().copied().collect();
    let (tr, te) = eval(&w)?;
    table.row(&[
        format!("alchemist[{}]", cfg.engine.as_str()),
        iters.to_string(),
        per.mean_pm_std(3),
        format!("{:.3}", res.timing("sim_secs") / iters.max(1) as f64),
        format!("{total:.2}"),
        format!("{:.3}", sx.secs + sy.secs + sw.secs),
        format!("{tr:.3}"),
        format!("{te:.3}"),
    ]);

    ac.shutdown_server()?;
    server.shutdown_on_request();

    println!();
    table.print();
    println!("(paper Table 2 shape: Alchemist per-iteration an order of magnitude below Spark)");
    Ok(())
}

/// Decimate a residual history for logging.
fn curve(res: &[f64]) -> Vec<f64> {
    if res.is_empty() {
        return vec![];
    }
    let stride = (res.len() / 8).max(1);
    let mut out: Vec<f64> = res.iter().step_by(stride).copied().collect();
    if *out.last().unwrap() != *res.last().unwrap() {
        out.push(*res.last().unwrap());
    }
    out
}
