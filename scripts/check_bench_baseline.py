#!/usr/bin/env python3
"""Diff a fresh bench artifact against its committed baseline.

Generalizes the old check_transfer_baseline.py to serve both bench
artifacts the repo pins:

* BENCH_transfer.json (bench "table3_transfer") — per-(executors,
  workers) cell push/pull GB/s;
* BENCH_compute.json  (bench "kernels", kind "compute") — per-(kernel,
  shape, threads) cell GFLOP/s, plus built-in speedup expectations
  evaluated on every fresh artifact: the packed gemm_nn at 512x512x512
  single-thread must be >= 2x the seed loop; threads=4 must be >= 2x
  threads=1 on the same shape; the runtime-dispatched AVX2 micro-kernel
  must beat the portable fallback (skipped on runners without AVX2 —
  those artifacts simply carry no gemm_nn_isa_avx2 cell); and the
  engine="auto" cost-model dispatcher must not lose to the packed
  kernel it routes composed GEMM to.

* BENCH_storage.json  (bench "table6_storage", kind "storage") —
  per-case ingest/egress GB/s, plus the v7 direct-ingest expectation:
  load_direct must be >= 2x load_push (the direct leg is one control
  RPC + a server-side mmap; the push leg moves every payload byte over
  TCP — if the ratio collapses, direct ingest has started copying).

The transfer artifact additionally carries "fabric_cells" (protocol
v8): the same collective over in-process mailboxes vs a tcp-loopback
mesh. Expectation: the tcp ring allreduce at the rendezvous (largest)
vector size must hold >= 0.5x the local-mailbox throughput — the
zero-copy writev path should keep loopback TCP within striking
distance of memcpy-speed mailboxes; a collapse means the rendezvous
leg started copying or serializing. Warns until a baseline with
fabric cells is pinned, fails after.

Since protocol v9 it also carries "sched_cells": the submit->Done
round-trip of a no-op task, streamed serially vs with two concurrent
tag lanes on one group vs from two concurrent tenants. Diffed on
tasks_per_sec like any other cell block — warns until a baseline
containing sched cells is pinned, fails on >tolerance regressions
after (a collapse here means dispatch, lane setup/retire, or
admission grew a stall).

CI's bench jobs run the smoke-size benches and call this script with the
fresh artifact and the repo's committed baseline. Outcomes:

* committed baseline is still a stub (no cells): emit a GitHub warning
  annotation (so the "pin a real baseline" follow-up cannot rot
  silently) and exit 0 — the compute expectations are still checked,
  but only warn.
* configs are incomparable (e.g. a smoke run against a full-size
  baseline): warn, exit 0.
* comparable: report per-cell throughput deltas; exit 1 if any cell
  regressed by more than --tolerance (default 50%, deliberately loose —
  CI runners are noisy; the committed baseline catches collapses, not
  5% drifts). With a pinned baseline the compute expectations also fail
  the run when unmet.

--update flips the script from checker to pinner: it takes FRESH (a CI
artifact or a local full-size run), stamps its provenance into
"status", and writes it to the BASELINE path as the exact pin-ready
baseline — commit the result. Refuses a FRESH with no cells (pinning an
empty baseline would disable the checker forever).

Usage: check_bench_baseline.py FRESH BASELINE [--tolerance 0.5] [--update]
"""

import argparse
import datetime
import json
import sys


def warn(msg: str) -> None:
    # GitHub Actions annotation; plain stderr elsewhere
    print(f"::warning::{msg}")
    print(f"WARNING: {msg}", file=sys.stderr)


def fail(msg: str) -> None:
    print(f"::error::{msg}")


def artifact_kind(doc: dict) -> str:
    kind = doc.get("kind")
    if kind:
        return kind
    if doc.get("bench") == "table3_transfer":
        return "transfer"
    if doc.get("bench") == "kernels":
        return "compute"
    if doc.get("bench") == "table6_storage":
        return "storage"
    return "unknown"


def pin_baseline(fresh_path: str, baseline_path: str) -> int:
    """Write FRESH to BASELINE as the committed, pin-ready baseline."""
    with open(fresh_path) as f:
        fresh = json.load(f)
    if not fresh.get("cells"):
        fail("refusing to pin a baseline with no cells "
             f"({fresh_path} has an empty 'cells' array — did the bench run?)")
        return 1
    stamp = datetime.datetime.now(datetime.timezone.utc).strftime("%Y-%m-%d")
    fresh["status"] = (
        f"baseline pinned {stamp} via check_bench_baseline.py --update "
        f"from {fresh_path}; regressions beyond --tolerance now fail CI"
    )
    with open(baseline_path, "w") as f:
        json.dump(fresh, f, indent=2)
        f.write("\n")
    cells = fresh["cells"]
    print(f"pinned {len(cells)} cell(s) from {fresh_path} -> {baseline_path}; "
          "commit the updated baseline to enable regression checking")
    return 0


def diff_cells(fresh, base, cell_key, metrics, tolerance):
    """Per-cell metric deltas; returns the list of regressions."""
    base_cells = {cell_key(c): c for c in base["cells"]}
    failures = []
    for cell in fresh.get("cells", []):
        ref = base_cells.get(cell_key(cell))
        if ref is None:
            continue
        for metric in metrics:
            got, want = cell.get(metric), ref.get(metric)
            if not isinstance(got, (int, float)) or not isinstance(want, (int, float)):
                continue
            if want <= 0:
                continue
            delta = (got - want) / want
            tag = (f"{describe_cell(cell)} {metric}: "
                   f"{got:.3f} vs baseline {want:.3f} ({delta:+.1%})")
            print(tag)
            if delta < -tolerance:
                failures.append(tag)
    return failures


def describe_cell(cell: dict) -> str:
    if "kernel" in cell:
        return (f"{cell.get('kernel')} {cell.get('m')}x{cell.get('n')}x"
                f"{cell.get('k')} t{cell.get('threads')}")
    if "lanes" in cell:
        return (f"sched {cell.get('case')} (tenants={cell.get('tenants')}, "
                f"lanes={cell.get('lanes')})")
    if "case" in cell:
        return str(cell.get("case"))
    if "fabric" in cell:
        return f"{cell.get('fabric')} {cell.get('op')} n={cell.get('elems')}"
    return f"e{cell.get('executors')}xw{cell.get('workers')}"


def check_storage_expectations(fresh: dict, pinned: bool) -> int:
    """The v7 direct-ingest speedup, evaluated on FRESH alone.

    load_direct is one control RPC after which workers map their file
    shards; load_push moves every payload byte over TCP. At any real
    dataset size the ratio is enormous, so the 2x target doubles as its
    own hard floor — warn while the baseline is a stub, fail after."""
    cells = {c.get("case"): c.get("gbps") for c in fresh.get("cells", [])}
    direct, push = cells.get("load_direct"), cells.get("load_push")
    if not isinstance(direct, (int, float)) or not isinstance(push, (int, float)) \
            or push <= 0:
        warn("storage expectation 'direct_vs_push' not evaluable "
             "(missing load_direct / load_push cells) — skipping")
        return 0
    ratio = direct / push
    tag = (f"storage expectation 'direct_vs_push': {direct:.2f} vs {push:.2f} "
           f"GB/s ({ratio:.2f}x, want >= 2.0x)")
    if ratio >= 2.0:
        print(tag + " OK")
        return 0
    if pinned:
        fail(tag + " UNMET")
        return 1
    warn(tag + " UNMET")
    return 0


def check_fabric_expectations(fresh: dict, pinned: bool) -> int:
    """The v8 rank-fabric floor, evaluated on FRESH alone.

    At the largest benched vector size the allreduce takes the
    bandwidth-optimal ring over the gathered-writev rendezvous path;
    tcp-loopback must hold >= 0.5x the local-mailbox throughput. The
    `pinned` flag here is whether the committed baseline carries
    fabric cells at all, so pre-v8 pins keep warning instead of
    failing."""
    cells = [c for c in fresh.get("fabric_cells", [])
             if c.get("op") == "allreduce"
             and isinstance(c.get("elems"), int)
             and isinstance(c.get("gbps"), (int, float))]
    if not cells:
        warn("fabric expectation 'tcp_vs_local' not evaluable "
             "(no allreduce fabric_cells) — skipping")
        return 0
    elems = max(c["elems"] for c in cells)
    by_fabric = {c.get("fabric"): c["gbps"] for c in cells
                 if c["elems"] == elems}
    tcp, local = by_fabric.get("tcp"), by_fabric.get("local")
    if not isinstance(tcp, (int, float)) or not isinstance(local, (int, float)) \
            or local <= 0:
        warn("fabric expectation 'tcp_vs_local' not evaluable "
             "(missing tcp/local allreduce cells) — skipping")
        return 0
    ratio = tcp / local
    tag = (f"fabric expectation 'tcp_vs_local' (allreduce, {elems} elems): "
           f"{tcp:.2f} vs {local:.2f} GB/s ({ratio:.2f}x, want >= 0.5x)")
    if ratio >= 0.5:
        print(tag + " OK")
        return 0
    if pinned:
        fail(tag + " UNMET")
        return 1
    warn(tag + " UNMET")
    return 0


def check_compute_expectations(fresh: dict, pinned: bool) -> int:
    """The acceptance-criteria speedups, evaluated on FRESH alone.

    Both warn while the committed baseline is still a stub. Once one is
    pinned: packed_vs_seed fails below its 2x target (the packed kernel
    has ~4x of headroom, runner noise cannot trip it); the threads=4
    scaling expectation keeps warning below its 2x target but only
    *fails* below a 1.5x hard floor — standard CI runners are 4 vCPUs =
    2 physical cores with SMT, where an FMA-port-bound f64 GEMM tops out
    right around 2x, so a hard 2x gate would flake on every PR, while a
    genuine scaling collapse (~1x) still cannot slip through even if the
    per-cell gflops diff's loose tolerance would have let it."""
    cells = {}
    for c in fresh.get("cells", []):
        key = (c.get("kernel"), c.get("m"), c.get("n"), c.get("k"),
               c.get("threads"))
        cells[key] = c.get("gflops")

    rc = 0

    def expect(label, num_key, den_key, want, hard_floor):
        nonlocal rc
        num, den = cells.get(num_key), cells.get(den_key)
        if not isinstance(num, (int, float)) or not isinstance(den, (int, float)) \
                or den <= 0:
            warn(f"compute expectation '{label}' not evaluable "
                 f"(missing cells {num_key} / {den_key}) — skipping")
            return
        ratio = num / den
        tag = (f"compute expectation '{label}': {num:.2f} vs {den:.2f} GFLOP/s "
               f"({ratio:.2f}x, want >= {want}x)")
        if ratio >= want:
            print(tag + " OK")
        elif pinned and ratio < hard_floor:
            fail(tag + f" UNMET (below the {hard_floor}x hard floor)")
            rc = 1
        else:
            warn(tag + " UNMET")

    shape = (512, 512, 512)
    expect("packed_vs_seed",
           ("gemm_nn", *shape, 1), ("gemm_nn_seed", *shape, 1), 2.0, 2.0)
    expect("scaling",
           ("gemm_nn", *shape, 4), ("gemm_nn", *shape, 1), 2.0, 1.5)
    # runtime ISA dispatch: the AVX2 micro-kernel must beat the portable
    # fallback on hosts that have it (non-AVX2 runners emit no avx2 cell,
    # so expect() downgrades this to a skip). Target 1.2x with a 1.0x
    # hard floor: if dispatch ever picks a path no faster than portable,
    # the whole mechanism is dead weight.
    expect("isa_dispatch",
           ("gemm_nn_isa_avx2", *shape, 1), ("gemm_nn_isa_fallback", *shape, 1),
           1.2, 1.0)
    # cost-model dispatch: auto routes composed GEMM to the packed native
    # kernels, so it must track them. Want parity; the 0.9x hard floor
    # absorbs run-to-run runner noise between the two measurements while
    # still catching a dispatcher that routes somewhere slower.
    for t in (1, 4):
        expect(f"auto_vs_packed_t{t}",
               ("gemm_nn_auto", *shape, t), ("gemm_nn", *shape, t), 1.0, 0.9)
    return rc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh")
    ap.add_argument("baseline")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="max fractional throughput regression per cell")
    ap.add_argument("--update", action="store_true",
                    help="write FRESH to BASELINE as the pin-ready committed "
                         "baseline instead of diffing")
    args = ap.parse_args()

    if args.update:
        return pin_baseline(args.fresh, args.baseline)

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    kind = artifact_kind(fresh)
    if kind == "unknown":
        warn(f"unrecognized bench artifact {args.fresh} "
             f"(bench={fresh.get('bench')!r}); nothing checked")
        return 0
    pinned = bool(base.get("cells"))

    rc = 0
    if kind == "compute":
        # the speedup expectations don't need a baseline — run them first
        # so a stub baseline still surfaces a slow kernel
        rc |= check_compute_expectations(fresh, pinned)
    elif kind == "storage":
        rc |= check_storage_expectations(fresh, pinned)
    elif kind == "transfer":
        rc |= check_fabric_expectations(fresh, bool(base.get("fabric_cells")))

    if not pinned:
        warn(
            f"{kind} baseline is still the committed stub (no cells) — "
            "download the CI artifact (or run the bench locally) and pin it "
            "with scripts/check_bench_baseline.py --update FRESH BASELINE "
            "(see README 'Pinning a benchmark baseline')."
        )
        return rc

    if kind == "transfer":
        comparable = ("rows", "cols", "runs", "quick", "rows_per_frame",
                      "buf_bytes", "pull_stripe_rows", "pull_window")
        cell_key = lambda c: (c.get("executors"), c.get("workers"))  # noqa: E731
        metrics = ("push_gbps", "pull_gbps")
    elif kind == "storage":
        comparable = ("rows", "cols", "runs", "quick", "workers")
        cell_key = lambda c: c.get("case")  # noqa: E731
        metrics = ("gbps",)
    else:
        comparable = ("quick", "runs", "threads")
        cell_key = lambda c: (c.get("kernel"), c.get("m"), c.get("n"),  # noqa: E731
                              c.get("k"), c.get("threads"))
        metrics = ("gflops",)

    fc, bc = fresh.get("config", {}), base.get("config", {})
    mismatched = [k for k in comparable if fc.get(k) != bc.get(k)]
    if mismatched:
        warn(
            f"{kind} bench configs are not comparable "
            f"(differ in {', '.join(mismatched)}); skipping the diff. "
            "Regenerate the baseline at the CI smoke size or run CI at "
            "the baseline size to re-enable regression checking."
        )
        return rc

    if not fresh.get("cells"):
        # the baseline has real numbers but this run produced none — the
        # exact collapse the check exists to catch must not pass silently
        fail(f"fresh {args.fresh} has no cells to compare against the "
             "pinned baseline (bench produced no results?)")
        return 1

    failures = diff_cells(fresh, base, cell_key, metrics, args.tolerance)
    if kind == "transfer" and base.get("fabric_cells"):
        fabric_key = lambda c: (c.get("fabric"), c.get("op"),  # noqa: E731
                                c.get("elems"))
        failures += diff_cells(
            {"cells": fresh.get("fabric_cells", [])},
            {"cells": base["fabric_cells"]},
            fabric_key, ("gbps",), args.tolerance)
    if kind == "transfer":
        if base.get("sched_cells"):
            sched_key = lambda c: (c.get("case"), c.get("tenants"),  # noqa: E731
                                   c.get("lanes"), c.get("tasks"))
            failures += diff_cells(
                {"cells": fresh.get("sched_cells", [])},
                {"cells": base["sched_cells"]},
                sched_key, ("tasks_per_sec",), args.tolerance)
        elif fresh.get("sched_cells"):
            warn("transfer baseline has no sched_cells (pre-v9 pin) — "
                 "scheduler round-trip diff skipped until a baseline "
                 "containing them is pinned")
    if failures:
        for f_ in failures:
            fail(f"{kind} throughput regression: {f_}")
        return 1
    print(f"{kind} bench within tolerance of the committed baseline")
    return rc


if __name__ == "__main__":
    sys.exit(main())
