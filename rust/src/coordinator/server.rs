//! The Alchemist driver: control-socket sessions, matrix handles, SPMD
//! task dispatch (paper §3.1.1).

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::collectives::LocalComm;
use crate::config::Config;
use crate::distmat::RowBlockLayout;
use crate::net::{Framed, Server};
use crate::protocol::{ControlMsg, MatrixInfo, Params, PROTOCOL_VERSION};

use super::registry::Registry;
use super::worker::{alloc_all, handle_data_conn, worker_main, WorkerCmd, WorkerShared};

/// Driver-side record of a live distributed matrix.
#[derive(Debug, Clone)]
struct HandleMeta {
    info: MatrixInfo,
    layout: RowBlockLayout,
}

struct Driver {
    #[allow(dead_code)] // kept for future per-session config introspection
    cfg: Config,
    workers: Vec<Arc<WorkerShared>>,
    senders: Vec<mpsc::Sender<WorkerCmd>>,
    registry: Registry,
    next_id: AtomicU64,
    next_session: AtomicU64,
    handles: Mutex<HashMap<u64, HandleMeta>>,
    /// One SPMD task at a time (the workers are a single MPI-style group).
    task_lock: Mutex<()>,
    stopping: AtomicBool,
    /// Stop flags of every accept loop (control + per-worker data).
    listener_stops: Mutex<Vec<Arc<AtomicBool>>>,
    control_addr: Mutex<String>,
}

impl Driver {
    /// Flip every stop flag, end the worker loops, and wake all accept
    /// loops so their threads can exit.
    fn stop_all(&self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        for s in &self.senders {
            let _ = s.send(WorkerCmd::Shutdown);
        }
        for flag in self.listener_stops.lock().unwrap().iter() {
            flag.store(true, Ordering::SeqCst);
        }
        for addr in self.worker_addrs() {
            let _ = TcpStream::connect(&addr);
        }
        let control = self.control_addr.lock().unwrap().clone();
        if !control.is_empty() {
            let _ = TcpStream::connect(&control);
        }
    }
}

impl Driver {
    fn worker_addrs(&self) -> Vec<String> {
        self.workers
            .iter()
            .map(|w| w.data_addr.lock().unwrap().clone())
            .collect()
    }

    fn create_matrix(&self, name: &str, rows: u64, cols: u64) -> crate::Result<ControlMsg> {
        anyhow::ensure!(rows > 0 && cols > 0, "matrix must be non-empty");
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let layout =
            RowBlockLayout::even(rows as usize, cols as usize, self.workers.len());
        alloc_all(&self.workers, id, name, &layout)?;
        self.handles.lock().unwrap().insert(
            id,
            HandleMeta {
                info: MatrixInfo { id, rows, cols, name: name.to_string() },
                layout: layout.clone(),
            },
        );
        Ok(ControlMsg::MatrixCreated { id, row_ranges: layout.to_wire() })
    }

    fn seal_matrix(&self, id: u64) -> crate::Result<ControlMsg> {
        let meta = self.handle(id)?;
        let mut received = 0;
        for w in &self.workers {
            received += w.store.lock().unwrap().seal(id)?;
        }
        anyhow::ensure!(
            received == meta.info.rows,
            "matrix {id}: sealed with {received} of {} rows",
            meta.info.rows
        );
        Ok(ControlMsg::MatrixSealed { id, rows_received: received })
    }

    fn handle(&self, id: u64) -> crate::Result<HandleMeta> {
        self.handles
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown matrix handle {id}"))
    }

    fn run_task(&self, lib_name: &str, routine: &str, params: &Params) -> crate::Result<ControlMsg> {
        let lib = self.registry.get(lib_name)?;
        let _guard = self.task_lock.lock().unwrap();
        // reserve an id window for the routine's outputs
        let out_base = self.next_id.fetch_add(64, Ordering::SeqCst);

        let mut replies = Vec::new();
        for sender in &self.senders {
            let (tx, rx) = mpsc::channel();
            sender
                .send(WorkerCmd::RunTask {
                    lib: lib.clone(),
                    routine: routine.to_string(),
                    params: params.clone(),
                    out_base,
                    reply: tx,
                })
                .map_err(|_| anyhow::anyhow!("worker thread is gone"))?;
            replies.push(rx);
        }
        let results: Vec<super::worker::TaskReply> = {
            let mut ok = Vec::new();
            let mut first_err = None;
            for rx in replies {
                match rx.recv().map_err(|_| anyhow::anyhow!("worker died mid-task"))? {
                    Ok(r) => ok.push(r),
                    Err(e) => first_err = first_err.or(Some(e)),
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
            ok
        };

        // consistency: every rank must report the same output set
        let r0 = &results[0];
        for r in &results[1..] {
            anyhow::ensure!(
                r.outputs.len() == r0.outputs.len(),
                "ranks disagree on output count for {lib_name}.{routine}"
            );
        }
        let mut outputs = Vec::new();
        {
            let mut handles = self.handles.lock().unwrap();
            for meta in &r0.outputs {
                let layout = self.workers[0]
                    .store
                    .lock()
                    .unwrap()
                    .get(meta.id)?
                    .layout
                    .clone();
                let info = MatrixInfo {
                    id: meta.id,
                    rows: meta.rows,
                    cols: meta.cols,
                    name: meta.name.clone(),
                };
                handles.insert(meta.id, HandleMeta { info: info.clone(), layout });
                outputs.push(info);
            }
        }

        // timings: rank-0 laps + aggregated cluster metrics
        let mut timings = r0.timings.clone();
        let lap = |r: &super::worker::TaskReply, name: &str| -> f64 {
            r.timings
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, s)| *s)
                .unwrap_or(0.0)
        };
        let sim_secs = results
            .iter()
            .map(|r| lap(r, "cpu_busy") + lap(r, "comm_sim"))
            .fold(0.0f64, f64::max);
        timings.push(("sim_secs".into(), sim_secs));

        Ok(ControlMsg::TaskDone { outputs, scalars: r0.scalars.clone(), timings })
    }

    fn fetch_matrix(&self, id: u64) -> crate::Result<ControlMsg> {
        let meta = self.handle(id)?;
        Ok(ControlMsg::FetchReady {
            info: meta.info,
            row_ranges: meta.layout.to_wire(),
        })
    }

    fn free_matrix(&self, id: u64) -> crate::Result<ControlMsg> {
        let existed = self.handles.lock().unwrap().remove(&id).is_some();
        anyhow::ensure!(existed, "unknown matrix handle {id}");
        for w in &self.workers {
            w.store.lock().unwrap().free(id);
        }
        Ok(ControlMsg::Freed { id })
    }

    fn list_matrices(&self) -> ControlMsg {
        let handles = self.handles.lock().unwrap();
        let mut infos: Vec<MatrixInfo> =
            handles.values().map(|m| m.info.clone()).collect();
        infos.sort_by_key(|i| i.id);
        ControlMsg::MatrixList { infos }
    }
}

/// Handle to a running server; dropping does NOT stop it — call
/// [`ServerHandle::shutdown`] (or send `ControlMsg::Shutdown` as a
/// client).
pub struct ServerHandle {
    pub control_addr: String,
    pub worker_addrs: Vec<String>,
    threads: Vec<JoinHandle<()>>,
    driver: Arc<Driver>,
}

impl ServerHandle {
    /// Stop the server from the owning process (benches/tests).
    pub fn shutdown(mut self) {
        self.driver.stop_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }

    /// Block until some client sends `ControlMsg::Shutdown` (the
    /// `alchemist serve` foreground mode).
    pub fn shutdown_on_request(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// The Alchemist server factory.
pub struct AlchemistServer;

impl AlchemistServer {
    /// Start a driver with `num_workers` worker ranks on ephemeral
    /// localhost ports. Returns once all sockets are listening.
    pub fn start(cfg: Config, num_workers: usize) -> crate::Result<ServerHandle> {
        anyhow::ensure!(num_workers >= 1, "need at least one worker");
        let mut threads = Vec::new();

        // worker shared state + comm group
        let comms = LocalComm::group(num_workers, Some(cfg.simnet.clone()));
        let mut workers = Vec::new();
        let mut senders = Vec::new();
        let mut worker_addrs = Vec::new();
        let mut listener_stops = Vec::new();

        for (rank, comm) in comms.into_iter().enumerate() {
            let shared = Arc::new(WorkerShared {
                rank,
                store: Mutex::new(super::store::MatrixStore::new(rank)),
                data_addr: Mutex::new(String::new()),
            });
            // data listener
            let listener = Server::bind(0)?;
            *shared.data_addr.lock().unwrap() = listener.addr().to_string();
            worker_addrs.push(listener.addr().to_string());
            listener_stops.push(listener.stop_flag());
            {
                let shared = shared.clone();
                let cfg = cfg.clone();
                threads.push(std::thread::spawn(move || {
                    let shared2 = shared.clone();
                    let _ = listener.serve(move |stream| {
                        handle_data_conn(&shared2, stream, &cfg);
                    });
                }));
            }
            // command loop
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            {
                let shared = shared.clone();
                let cfg = cfg.clone();
                threads.push(std::thread::spawn(move || {
                    worker_main(shared, comm, cfg, rx);
                }));
            }
            workers.push(shared);
        }

        let control = Server::bind(0)?;
        let control_addr = control.addr().to_string();
        listener_stops.push(control.stop_flag());
        let driver = Arc::new(Driver {
            cfg: cfg.clone(),
            workers,
            senders,
            registry: Registry::new(),
            next_id: AtomicU64::new(1),
            next_session: AtomicU64::new(1),
            handles: Mutex::new(HashMap::new()),
            task_lock: Mutex::new(()),
            stopping: AtomicBool::new(false),
            listener_stops: Mutex::new(listener_stops),
            control_addr: Mutex::new(control_addr.clone()),
        });

        {
            let driver = driver.clone();
            let buf = cfg.transfer.buf_bytes;
            threads.push(std::thread::spawn(move || {
                let _ = control.serve(move |stream| {
                    handle_control_conn(&driver, stream, buf);
                });
            }));
        }

        log::info!(
            "alchemist server up: control {control_addr}, {num_workers} workers, engine {}",
            cfg.engine.as_str()
        );
        Ok(ServerHandle {
            control_addr,
            worker_addrs: driver.worker_addrs(),
            threads,
            driver,
        })
    }
}

fn handle_control_conn(driver: &Arc<Driver>, stream: TcpStream, buf_bytes: usize) {
    if driver.stopping.load(Ordering::SeqCst) {
        return; // wake-up connection during shutdown
    }
    let mut framed = match Framed::tcp(stream, buf_bytes) {
        Ok(f) => f,
        Err(e) => {
            log::warn!("control conn setup failed: {e}");
            return;
        }
    };
    loop {
        let msg = match framed.recv_ctrl() {
            Ok(m) => m,
            Err(_) => return, // client went away
        };
        let reply = match msg {
            ControlMsg::Handshake { client_name, version } => {
                if version != PROTOCOL_VERSION {
                    Ok(ControlMsg::Error {
                        message: format!(
                            "protocol version mismatch: client {version}, server {PROTOCOL_VERSION}"
                        ),
                    })
                } else {
                    let session_id =
                        driver.next_session.fetch_add(1, Ordering::SeqCst);
                    log::info!("session {session_id}: client {client_name:?} connected");
                    Ok(ControlMsg::HandshakeAck {
                        session_id,
                        version: PROTOCOL_VERSION,
                        worker_addrs: driver.worker_addrs(),
                    })
                }
            }
            ControlMsg::RegisterLibrary { name, path } => driver
                .registry
                .register(&name, &path)
                .map(|()| ControlMsg::LibraryRegistered { name }),
            ControlMsg::CreateMatrix { name, rows, cols } => {
                driver.create_matrix(&name, rows, cols)
            }
            ControlMsg::SealMatrix { id } => driver.seal_matrix(id),
            ControlMsg::RunTask { lib, routine, params } => {
                driver.run_task(&lib, &routine, &params)
            }
            ControlMsg::FetchMatrix { id } => driver.fetch_matrix(id),
            ControlMsg::FreeMatrix { id } => driver.free_matrix(id),
            ControlMsg::ListMatrices => Ok(driver.list_matrices()),
            ControlMsg::Shutdown => {
                driver.stop_all();
                let _ = framed.send_ctrl(&ControlMsg::Bye);
                return;
            }
            other => Ok(ControlMsg::Error {
                message: format!("unexpected control message: {other:?}"),
            }),
        };
        let out = match reply {
            Ok(m) => m,
            Err(e) => ControlMsg::Error { message: format!("{e:#}") },
        };
        if framed.send_ctrl(&out).is_err() {
            return;
        }
    }
}
