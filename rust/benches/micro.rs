//! Microbenchmarks: collectives, local GEMM roofline, protocol codec
//! throughput — the substrate numbers the end-to-end results decompose
//! into.

mod bench_common;

use alchemist::cli::Args;
use alchemist::collectives::algorithms::infallible::{allreduce_sum, broadcast};
use alchemist::collectives::{Communicator, LocalComm, TAG_WINDOW};
use alchemist::compute::{Engine, GemmVariant, NativeEngine};
use alchemist::distmat::LocalMatrix;
use alchemist::metrics::{Stats, Table};
use alchemist::protocol::DataMsg;
use alchemist::util::prng::Rng;
use alchemist::util::timer::time;
use bench_common::is_quick;

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let quick = is_quick(&args);

    gemm_roofline(quick);
    collectives_micro(quick);
    codec_micro(quick);
    Ok(())
}

fn gemm_roofline(quick: bool) {
    let mut table = Table::new(
        "micro: native GEMM roofline (seed loop vs packed kernel)",
        &["n", "kernel", "threads", "secs", "GFLOP/s"],
    );
    let sizes: &[usize] = if quick { &[256] } else { &[128, 256, 512, 1024] };
    let mut rng = Rng::new(1);
    for &n in sizes {
        let a = LocalMatrix::from_fn(n, n, |_, _| rng.normal());
        let b = LocalMatrix::from_fn(n, n, |_, _| rng.normal());
        let reps = if n <= 256 { 5 } else { 2 };
        let flops = 2.0 * (n as f64).powi(3);

        let mut run = |kernel: &str, threads: usize, f: &mut dyn FnMut()| {
            f(); // warm
            let mut stats = Stats::new();
            for _ in 0..reps {
                let (_, secs) = time(&mut *f);
                stats.push(secs);
            }
            table.row(&[
                n.to_string(),
                kernel.to_string(),
                threads.to_string(),
                format!("{:.4}", stats.mean()),
                format!("{:.2}", flops / stats.mean() / 1e9),
            ]);
        };

        run("seed i-k-j", 1, &mut || {
            let mut c = LocalMatrix::zeros(n, n);
            bench_common::gemm_nn_seed(&mut c, &a, &b);
        });
        for threads in [1usize, 4] {
            let mut engine = NativeEngine::with_threads(threads);
            run("packed", threads, &mut || {
                let mut c = LocalMatrix::zeros(n, n);
                engine.gemm(GemmVariant::NN, &mut c, &a, &b).unwrap();
            });
        }
    }
    table.print();
}

fn collectives_micro(quick: bool) {
    let mut table = Table::new(
        "micro: collectives (in-proc comm, wall time at rank 0)",
        &["op", "ranks", "elements", "secs (mean±sd)"],
    );
    let sizes: &[usize] = if quick { &[1024] } else { &[1024, 65_536, 1_048_576] };
    for &n in sizes {
        for &p in &[2usize, 4, 8] {
            for op in ["allreduce", "broadcast"] {
                let reps = if n > 100_000 { 3 } else { 10 };
                let mut stats = Stats::new();
                for _ in 0..reps {
                    let comms = LocalComm::group(p, None);
                    let mut handles = Vec::new();
                    for c in comms {
                        let op = op.to_string();
                        handles.push(std::thread::spawn(move || {
                            let mut buf = vec![c.rank() as f64; n];
                            let t0 = std::time::Instant::now();
                            match op.as_str() {
                                "allreduce" => {
                                    allreduce_sum(&c, TAG_WINDOW, &mut buf)
                                }
                                _ => broadcast(&c, TAG_WINDOW, 0, &mut buf),
                            }
                            (c.rank(), t0.elapsed().as_secs_f64())
                        }));
                    }
                    for h in handles {
                        let (rank, secs) = h.join().unwrap();
                        if rank == 0 {
                            stats.push(secs);
                        }
                    }
                }
                table.row(&[
                    op.into(),
                    p.to_string(),
                    n.to_string(),
                    stats.mean_pm_std(6),
                ]);
            }
        }
    }
    table.print();
}

fn codec_micro(quick: bool) {
    let mut table = Table::new(
        "micro: wire codec throughput (PushRows encode+decode)",
        &["rows/frame", "bytes/frame", "encode GB/s", "decode GB/s"],
    );
    let cols = 512usize;
    let frames: &[usize] = if quick { &[64] } else { &[1, 8, 64, 512] };
    let mut rng = Rng::new(2);
    for &rows in frames {
        let data: Vec<f64> = (0..rows * cols).map(|_| rng.normal()).collect();
        let msg = DataMsg::PushRows {
            matrix_id: 1,
            start_row: 0,
            nrows: rows as u32,
            ncols: cols as u32,
            data,
        };
        let bytes = rows * cols * 8;
        let reps = (200_000_000 / bytes.max(1)).clamp(10, 5000);
        let (encoded, enc_secs) = time(|| {
            let mut last = Vec::new();
            for _ in 0..reps {
                last = msg.encode();
            }
            last
        });
        let (_, dec_secs) = time(|| {
            for _ in 0..reps {
                let _ = DataMsg::decode(&encoded).unwrap();
            }
        });
        table.row(&[
            rows.to_string(),
            bytes.to_string(),
            format!("{:.2}", bytes as f64 * reps as f64 / enc_secs / 1e9),
            format!("{:.2}", bytes as f64 * reps as f64 / dec_secs / 1e9),
        ]);
    }
    table.print();
}
