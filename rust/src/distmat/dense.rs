//! Dense row-major f64 matrix with a blocked native GEMM.
//!
//! This is the local-block storage for [`super::DistShard`] and the compute
//! floor for the engine ablation: `compute::NativeEngine` calls the blocked
//! kernels here, while the XLA/Pallas engines only use this type as a
//! container. The GEMM blocks for L1/L2 locality and keeps the innermost
//! loop a contiguous `f64` FMA chain the compiler can vectorize.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct LocalMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// Cache block edge for the native GEMM (tuned in the perf pass; see
/// EXPERIMENTS.md §Perf).
const MC: usize = 64;

impl LocalMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        LocalMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_data(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        LocalMatrix { rows, cols, data }
    }

    /// Build from a row-generating closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        LocalMatrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Rows `[a, b)` as a new matrix.
    pub fn slice_rows(&self, a: usize, b: usize) -> LocalMatrix {
        assert!(a <= b && b <= self.rows);
        LocalMatrix {
            rows: b - a,
            cols: self.cols,
            data: self.data[a * self.cols..b * self.cols].to_vec(),
        }
    }

    /// Copy `src` into rows starting at `at`.
    pub fn write_rows(&mut self, at: usize, src: &LocalMatrix) {
        assert_eq!(src.cols, self.cols);
        assert!(at + src.rows <= self.rows);
        self.data[at * self.cols..(at + src.rows) * self.cols]
            .copy_from_slice(&src.data);
    }

    /// Columns `[a, b)` as a new matrix.
    pub fn slice_cols(&self, a: usize, b: usize) -> LocalMatrix {
        assert!(a <= b && b <= self.cols);
        let mut out = LocalMatrix::zeros(self.rows, b - a);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[a..b]);
        }
        out
    }

    pub fn transpose(&self) -> LocalMatrix {
        let mut out = LocalMatrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Pad to `(rows, cols)` with zeros (no-op if already that size).
    pub fn padded(&self, rows: usize, cols: usize) -> LocalMatrix {
        assert!(rows >= self.rows && cols >= self.cols);
        if rows == self.rows && cols == self.cols {
            return self.clone();
        }
        let mut out = LocalMatrix::zeros(rows, cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Top-left `(rows, cols)` corner (inverse of [`padded`]).
    pub fn shrunk(&self, rows: usize, cols: usize) -> LocalMatrix {
        assert!(rows <= self.rows && cols <= self.cols);
        if rows == self.rows && cols == self.cols {
            return self.clone();
        }
        let mut out = LocalMatrix::zeros(rows, cols);
        for i in 0..rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..cols]);
        }
        out
    }

    /// `[A A ... A]` — column-wise tiling (Figure 3 construction).
    pub fn tile_cols(&self, times: usize) -> LocalMatrix {
        assert!(times >= 1);
        let mut out = LocalMatrix::zeros(self.rows, self.cols * times);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for t in 0..times {
                dst[t * self.cols..(t + 1) * self.cols].copy_from_slice(src);
            }
        }
        out
    }

    pub fn fro_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    pub fn fro_norm(&self) -> f64 {
        self.fro_sq().sqrt()
    }

    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &LocalMatrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Per-column dot products: `out[j] = Σ_i a[i,j]·b[i,j]` (block-CG
    /// needs one inner product per right-hand side).
    pub fn col_dots(&self, other: &LocalMatrix) -> Vec<f64> {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let (ra, rb) = (self.row(i), other.row(i));
            for j in 0..self.cols {
                out[j] += ra[j] * rb[j];
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &LocalMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    // ---- blocked native GEMM: C += op(A)·op(B) ----

    /// `self += a · b` (a: m×k, b: k×n, self: m×n).
    pub fn gemm_nn(&mut self, a: &LocalMatrix, b: &LocalMatrix) {
        assert_eq!(a.cols, b.rows);
        assert_eq!((self.rows, self.cols), (a.rows, b.cols));
        let (m, n, k) = (a.rows, b.cols, a.cols);
        // i-k-j loop with row-major B keeps the inner loop contiguous.
        for i0 in (0..m).step_by(MC) {
            let i1 = (i0 + MC).min(m);
            for k0 in (0..k).step_by(MC) {
                let k1 = (k0 + MC).min(k);
                for i in i0..i1 {
                    let arow = &a.data[i * k..(i + 1) * k];
                    let crow = &mut self.data[i * n..(i + 1) * n];
                    for kk in k0..k1 {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b.data[kk * n..(kk + 1) * n];
                        for j in 0..n {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
    }

    /// `self += aᵀ · b` (a stored k×m, b: k×n, self: m×n).
    pub fn gemm_tn(&mut self, a: &LocalMatrix, b: &LocalMatrix) {
        assert_eq!(a.rows, b.rows);
        assert_eq!((self.rows, self.cols), (a.cols, b.cols));
        let (m, n, k) = (a.cols, b.cols, a.rows);
        for k0 in (0..k).step_by(MC) {
            let k1 = (k0 + MC).min(k);
            for kk in k0..k1 {
                let arow = &a.data[kk * m..(kk + 1) * m];
                let brow = &b.data[kk * n..(kk + 1) * n];
                for i in 0..m {
                    let aki = arow[i];
                    if aki == 0.0 {
                        continue;
                    }
                    let crow = &mut self.data[i * n..(i + 1) * n];
                    for j in 0..n {
                        crow[j] += aki * brow[j];
                    }
                }
            }
        }
    }

    /// `self += a · bᵀ` (a: m×k, b stored n×k, self: m×n).
    pub fn gemm_nt(&mut self, a: &LocalMatrix, b: &LocalMatrix) {
        assert_eq!(a.cols, b.cols);
        assert_eq!((self.rows, self.cols), (a.rows, b.rows));
        let (m, n, k) = (a.rows, b.rows, a.cols);
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut self.data[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                crow[j] += acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random(rng: &mut Rng, r: usize, c: usize) -> LocalMatrix {
        LocalMatrix::from_fn(r, c, |_, _| rng.normal())
    }

    /// Naive reference product.
    fn gemm_ref(a: &LocalMatrix, b: &LocalMatrix) -> LocalMatrix {
        let mut c = LocalMatrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn gemm_variants_match_reference() {
        let mut rng = Rng::new(1);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (5, 7, 3), (33, 17, 65), (128, 64, 70)] {
            let a = random(&mut rng, m, k);
            let b = random(&mut rng, k, n);
            let want = gemm_ref(&a, &b);

            let mut c = LocalMatrix::zeros(m, n);
            c.gemm_nn(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-10, "nn {m}x{n}x{k}");

            let mut c = LocalMatrix::zeros(m, n);
            c.gemm_tn(&a.transpose(), &b);
            assert!(c.max_abs_diff(&want) < 1e-10, "tn {m}x{n}x{k}");

            let mut c = LocalMatrix::zeros(m, n);
            c.gemm_nt(&a, &b.transpose());
            assert!(c.max_abs_diff(&want) < 1e-10, "nt {m}x{n}x{k}");
        }
    }

    #[test]
    fn gemm_accumulates() {
        let mut rng = Rng::new(2);
        let a = random(&mut rng, 4, 4);
        let b = random(&mut rng, 4, 4);
        let seed = random(&mut rng, 4, 4);
        let mut c = seed.clone();
        c.gemm_nn(&a, &b);
        let mut want = gemm_ref(&a, &b);
        want.axpy(1.0, &seed);
        assert!(c.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn pad_shrink_roundtrip() {
        let mut rng = Rng::new(3);
        let a = random(&mut rng, 5, 7);
        let p = a.padded(8, 16);
        assert_eq!(p.rows(), 8);
        assert_eq!(p.fro_sq(), a.fro_sq()); // zero padding adds nothing
        assert_eq!(p.shrunk(5, 7), a);
    }

    #[test]
    fn slice_write_roundtrip() {
        let mut rng = Rng::new(4);
        let a = random(&mut rng, 6, 3);
        let s = a.slice_rows(2, 5);
        let mut b = LocalMatrix::zeros(6, 3);
        b.write_rows(2, &s);
        assert_eq!(b.slice_rows(2, 5), s);
        assert_eq!(b.row(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn transpose_involution_and_slice_cols() {
        let mut rng = Rng::new(5);
        let a = random(&mut rng, 4, 9);
        assert_eq!(a.transpose().transpose(), a);
        let c = a.slice_cols(2, 5);
        for i in 0..4 {
            for j in 0..3 {
                assert_eq!(c.get(i, j), a.get(i, j + 2));
            }
        }
    }

    #[test]
    fn col_dots_matches_naive() {
        let mut rng = Rng::new(6);
        let a = random(&mut rng, 10, 4);
        let b = random(&mut rng, 10, 4);
        let got = a.col_dots(&b);
        for j in 0..4 {
            let want: f64 = (0..10).map(|i| a.get(i, j) * b.get(i, j)).sum();
            assert!((got[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_is_gemm_neutral() {
        let mut rng = Rng::new(7);
        let a = random(&mut rng, 6, 6);
        let mut c = LocalMatrix::zeros(6, 6);
        c.gemm_nn(&a, &LocalMatrix::identity(6));
        assert!(c.max_abs_diff(&a) < 1e-14);
    }
}
