//! Integration: session-scoped worker groups and the concurrent
//! multi-tenant scheduler — disjoint groups make progress simultaneously,
//! oversubscribed requests queue FIFO until a teardown frees capacity,
//! and teardown frees exactly the departing session's matrices.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use alchemist::client::AlchemistContext;
use alchemist::config::{Config, EngineKind};
use alchemist::coordinator::AlchemistServer;
use alchemist::distmat::LocalMatrix;
use alchemist::protocol::Params;
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::util::prng::Rng;

fn native_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.engine = EngineKind::Native;
    cfg
}

fn random_matrix(seed: u64, rows: usize, cols: usize) -> LocalMatrix {
    let mut rng = Rng::new(seed);
    LocalMatrix::from_fn(rows, cols, |_, _| rng.normal())
}

#[test]
fn disjoint_groups_run_tasks_concurrently() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 4).unwrap();
    let addr = server.control_addr.clone();

    // baseline: one 2-worker session, one sleep task
    let mut ac0 =
        AlchemistContext::connect_with_workers(&addr, &cfg, 1, 2).unwrap();
    assert_eq!(ac0.granted_workers, 2);
    assert_eq!(ac0.num_workers(), 2);
    ac0.register_library("elemental", "builtin:elemental").unwrap();
    let t0 = Instant::now();
    let res = ac0
        .run_task("elemental", "sleep", Params::new().with_i64("millis", 400))
        .unwrap();
    let single = t0.elapsed().as_secs_f64();
    // the task ran on the session's own 2-rank group, not the 4-rank pool
    assert_eq!(res.scalars.i64("ranks").unwrap(), 2);
    ac0.stop();

    // two sessions on disjoint 2-worker groups sleep at the same time:
    // sleeps do not contend for cores, so overlap shows up in wallclock
    // even on a single-core box
    let t1 = Instant::now();
    let mut handles = Vec::new();
    let (addrs_tx, addrs_rx) = mpsc::channel();
    for i in 0..2u64 {
        let addr = addr.clone();
        let cfg = cfg.clone();
        let addrs_tx = addrs_tx.clone();
        handles.push(std::thread::spawn(move || {
            let mut ac =
                AlchemistContext::connect_with_workers(&addr, &cfg, 1, 2).unwrap();
            assert_eq!(ac.granted_workers, 2);
            addrs_tx.send(ac.worker_addrs.clone()).unwrap();
            ac.register_library("elemental", "builtin:elemental").unwrap();
            let res = ac
                .run_task(
                    "elemental",
                    "sleep",
                    Params::new().with_i64("millis", 400).with_i64("tenant", i as i64),
                )
                .unwrap();
            assert_eq!(res.scalars.i64("ranks").unwrap(), 2);
            ac.stop();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let combined = t1.elapsed().as_secs_f64();

    // the acceptance bound: two concurrent tasks cost < 1.8x one task
    assert!(
        combined < 1.8 * single,
        "tasks serialized: single {single:.3}s, combined {combined:.3}s"
    );

    // the two groups were disjoint worker sets
    let a: Vec<String> = addrs_rx.recv().unwrap();
    let b: Vec<String> = addrs_rx.recv().unwrap();
    assert!(a.iter().all(|x| !b.contains(x)), "groups overlap: {a:?} vs {b:?}");

    server.shutdown();
}

#[test]
fn oversubscribed_request_queues_until_teardown_grants() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    let a = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 1).unwrap();
    let b = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 1).unwrap();
    assert_eq!((a.granted_workers, b.granted_workers), (1, 1));

    // a third session wants the whole pool: it must queue, not error
    let (tx, rx) = mpsc::channel();
    let waiter = {
        let (addr, cfg) = (addr.clone(), cfg.clone());
        std::thread::spawn(move || {
            let granted = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 2)
                .map(|ac| ac.granted_workers);
            tx.send(granted).unwrap();
        })
    };
    std::thread::sleep(Duration::from_millis(300));
    assert!(rx.try_recv().is_err(), "request was admitted while pool was full");

    // freeing one worker is not enough for a 2-worker request
    a.stop();
    std::thread::sleep(Duration::from_millis(300));
    assert!(rx.try_recv().is_err(), "granted with only half the capacity free");

    // freeing the second worker admits the queued session
    b.stop();
    let granted = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("queued handshake never completed")
        .expect("queued handshake failed");
    assert_eq!(granted, 2);
    waiter.join().unwrap();

    // a request the pool can never satisfy fails immediately
    let err = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 3).unwrap_err();
    assert!(err.to_string().contains("only has"), "{err}");

    server.shutdown();
}

#[test]
fn queue_timeout_errors_instead_of_hanging() {
    let mut cfg = native_cfg();
    cfg.apply("scheduler.queue_timeout_s", "0.3").unwrap();
    let server = AlchemistServer::start(cfg.clone(), 1).unwrap();
    let addr = server.control_addr.clone();

    let holder = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 1).unwrap();
    let t0 = Instant::now();
    let err = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 1).unwrap_err();
    assert!(err.to_string().contains("timed out"), "{err}");
    assert!(t0.elapsed() >= Duration::from_millis(250), "timed out too early");
    holder.stop();
    server.shutdown();
}

#[test]
fn teardown_frees_only_the_departing_sessions_matrices() {
    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    let mut a = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 1).unwrap();
    let mut b = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 1).unwrap();
    a.register_library("elemental", "builtin:elemental").unwrap();

    let xa = random_matrix(1, 6, 3);
    let xb = random_matrix(2, 5, 2);
    let (al_a, _) = a.send_matrix("Xa", &IndexedRowMatrix::from_local(&xa, 2)).unwrap();
    let (al_b, _) = b.send_matrix("Xb", &IndexedRowMatrix::from_local(&xb, 2)).unwrap();
    // a also computes an output matrix server-side
    let res = a
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 8).with_i64("cols", 2).with_i64("seed", 3),
        )
        .unwrap();
    assert_eq!(res.outputs.len(), 1);
    assert_eq!(server.total_blocks(), 3);
    assert_eq!(server.active_sessions(), 2);

    // handles are namespaced: sessions list and free only their own
    let listed_a = a.list_matrices().unwrap();
    assert!(listed_a.iter().any(|(id, ..)| *id == al_a.id));
    assert!(!listed_a.iter().any(|(id, ..)| *id == al_b.id));
    let err = b.free(&al_a).unwrap_err();
    assert!(err.to_string().contains("unknown matrix handle"), "{err}");

    // a's teardown frees a's two matrices and nothing else
    a.stop();
    let t0 = Instant::now();
    while server.total_blocks() != 1 {
        assert!(t0.elapsed() < Duration::from_secs(5), "teardown never freed blocks");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(server.active_sessions(), 1);

    // b's matrix survived and still round-trips
    let (back, _) = b.to_indexed_row_matrix(&al_b, 1).unwrap();
    assert_eq!(back.to_local().unwrap(), xb);
    b.stop();

    let t0 = Instant::now();
    while server.total_blocks() != 0 || server.active_sessions() != 0 {
        assert!(t0.elapsed() < Duration::from_secs(5), "final teardown incomplete");
        std::thread::sleep(Duration::from_millis(20));
    }
    server.shutdown();
}

#[test]
fn data_plane_enforces_session_ownership() {
    use alchemist::net::Framed;
    use alchemist::protocol::DataMsg;

    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let addr = server.control_addr.clone();

    let mut a = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 1).unwrap();
    let b = AlchemistContext::connect_with_workers(&addr, &cfg, 1, 1).unwrap();
    let xa = random_matrix(4, 6, 2);
    let (al_a, _) = a.send_matrix("Xa", &IndexedRowMatrix::from_local(&xa, 1)).unwrap();

    // a raw connection to a's worker cannot pull without a handshake
    let mut data = Framed::connect(&a.worker_addrs[0], 1 << 16).unwrap();
    data.send_data_flush(&DataMsg::PullRows {
        matrix_id: al_a.id,
        start_row: 0,
        nrows: 1,
        start_col: 0,
        sel_cols: 0,
    })
    .unwrap();
    match data.recv_data().unwrap() {
        DataMsg::DataError { message } => {
            assert!(message.contains("handshake required"), "{message}")
        }
        other => panic!("{other:?}"),
    }

    // ...and cannot handshake as a session holding no group on this worker
    data.send_data_flush(&DataMsg::DataHandshake {
        session_id: b.session_id,
        executor_id: 0,
        rows_per_frame: 0,
    })
    .unwrap();
    match data.recv_data().unwrap() {
        DataMsg::DataError { message } => {
            assert!(message.contains("holds no group"), "{message}")
        }
        other => panic!("{other:?}"),
    }

    // a's own executors still work end-to-end
    let (back, _) = a.to_indexed_row_matrix(&al_a, 1).unwrap();
    assert_eq!(back.to_local().unwrap(), xa);

    a.stop();
    b.stop();
    server.shutdown();
}

#[test]
fn session_ops_require_handshake() {
    use alchemist::net::Framed;
    use alchemist::protocol::ControlMsg;

    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg, 1).unwrap();
    let mut control = Framed::connect(&server.control_addr, 1 << 16).unwrap();
    let err = control
        .call(&ControlMsg::CreateMatrix { name: "X".into(), rows: 4, cols: 2 })
        .unwrap_err();
    assert!(err.to_string().contains("handshake required"), "{err}");
    server.shutdown();
}
