//! Partitioned immutable collections (the RDD abstraction, minus lineage —
//! fault tolerance is out of scope for the performance study).

/// An in-memory partitioned collection.
#[derive(Debug, Clone)]
pub struct Rdd<T> {
    partitions: Vec<Vec<T>>,
}

impl<T> Rdd<T> {
    pub fn from_partitions(partitions: Vec<Vec<T>>) -> Self {
        Rdd { partitions }
    }

    /// Partition a flat collection evenly.
    pub fn parallelize(items: Vec<T>, num_partitions: usize) -> Self {
        let n = items.len();
        let ranges = crate::util::even_ranges(n, num_partitions.max(1));
        let mut iter = items.into_iter();
        let partitions = ranges
            .iter()
            .map(|&(a, b)| iter.by_ref().take(b - a).collect())
            .collect();
        Rdd { partitions }
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn count(&self) -> usize {
        self.partitions.iter().map(|p| p.len()).sum()
    }

    pub fn partitions(&self) -> &[Vec<T>] {
        &self.partitions
    }

    pub fn into_partitions(self) -> Vec<Vec<T>> {
        self.partitions
    }

    /// Flatten to a single vector (driver-side collect, no overheads here —
    /// the engine charges them).
    pub fn collect(self) -> Vec<T> {
        self.partitions.into_iter().flatten().collect()
    }

    /// Approximate in-memory size, used for the driver/cluster memory cap
    /// (Table 1's capability boundary).
    pub fn size_bytes(&self) -> usize
    where
        T: SizedBytes,
    {
        self.partitions
            .iter()
            .flat_map(|p| p.iter().map(|t| t.heap_bytes()))
            .sum()
    }
}

/// Heap payload estimate for the memory-cap model.
pub trait SizedBytes {
    fn heap_bytes(&self) -> usize;
}

impl SizedBytes for super::matrix::IndexedRow {
    fn heap_bytes(&self) -> usize {
        8 + self.vector.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelize_balances_and_preserves_order() {
        let r = Rdd::parallelize((0..10).collect(), 3);
        assert_eq!(r.num_partitions(), 3);
        assert_eq!(r.count(), 10);
        let sizes: Vec<usize> = r.partitions().iter().map(|p| p.len()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
        assert_eq!(r.collect(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn more_partitions_than_items() {
        let r = Rdd::parallelize(vec![1, 2], 5);
        assert_eq!(r.num_partitions(), 5);
        assert_eq!(r.count(), 2);
    }
}
