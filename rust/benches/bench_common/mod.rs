#![allow(dead_code)] // each bench uses a subset of these helpers
//! Shared helpers for the paper-table benches (harness = false mains;
//! criterion is not in the offline vendor set).

use alchemist::cli::Args;
use alchemist::config::Config;

/// Paper iteration count for the 10k-feature CG run (§4.1: "CG takes
/// approximately 526 iterations"); totals are extrapolated to this count
/// from the measured per-iteration mean, exactly as a full run would cost.
pub const PAPER_CG_ITERS: usize = 526;

/// Build the bench config: defaults + `--engine` + `--set k=v,...`
/// overrides shared by all benches.
pub fn bench_config(args: &Args) -> alchemist::Result<Config> {
    let mut cfg = Config::default();
    if let Some(engine) = args.get("engine") {
        cfg.apply("engine", engine)?;
    }
    if let Some(pairs) = args.get("set") {
        for pair in pairs.split(',') {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--set expects k=v, got {pair:?}"))?;
            cfg.apply(k.trim(), v.trim())?;
        }
    }
    Ok(cfg)
}

/// `--quick` trims sweeps for smoke runs.
pub fn is_quick(args: &Args) -> bool {
    args.flag("quick")
}

/// The seed-era native GEMM (pre-PR5 `LocalMatrix::gemm_nn`): MC-blocked
/// i-k-j loops with the `aik == 0.0` skip branch, no packing, single
/// thread. Kept verbatim as the compute-bench reference so
/// `BENCH_compute.json` records the packed kernel's speedup over the
/// floor it replaced (`check_bench_baseline.py` asserts ≥2x at 512³).
pub fn gemm_nn_seed(
    c: &mut alchemist::distmat::LocalMatrix,
    a: &alchemist::distmat::LocalMatrix,
    b: &alchemist::distmat::LocalMatrix,
) {
    const MC: usize = 64;
    assert_eq!(a.cols(), b.rows());
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()));
    let (m, n, k) = (a.rows(), b.cols(), a.cols());
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    for i0 in (0..m).step_by(MC) {
        let i1 = (i0 + MC).min(m);
        for k0 in (0..k).step_by(MC) {
            let k1 = (k0 + MC).min(k);
            for i in i0..i1 {
                let arow = &ad[i * k..(i + 1) * k];
                let crow = &mut cd[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bd[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

pub fn require_artifacts(cfg: &Config) -> bool {
    let ok = cfg.resolved_artifacts_dir().join("manifest.txt").exists();
    if !ok {
        println!("SKIP: artifacts missing; run `make artifacts` first");
    }
    ok
}
