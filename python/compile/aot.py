"""AOT compile path: lower every L2 graph to HLO *text* + a manifest.

Run once at build time (``make artifacts``); the rust runtime
(``rust/src/runtime``) loads ``artifacts/*.hlo.txt`` through
``HloModuleProto::from_text_file`` and compiles them on the PJRT CPU
client. HLO **text** is the interchange format, never
``lowered.compile().serialize()`` or proto bytes: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is listed in ``artifacts/manifest.txt`` as one
whitespace-separated ``key=value`` line, e.g.::

    name=pallas_gemm_nn_256x256x256 op=gemm_nn engine=pallas dtype=f64 \
        dims=256,256,256 inputs=256x256;256x256;256x256 outputs=256x256

The rust side resolves (op, dims, engine) -> executable via this manifest;
nothing in rust parses HLO beyond handing the text to XLA.

Usage: ``python -m compile.aot --out-dir ../artifacts [--quick]``
"""

from __future__ import annotations

import argparse
import hashlib
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple=True always).

    The rust loader unwraps the 1-/2-tuple; keeping every artifact a tuple
    makes the calling convention uniform.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class Spec:
    """One artifact: an L2 builder plus concrete example shapes."""

    def __init__(self, op, engine, dims, build, in_shapes, out_shapes,
                 block=128):
        self.op = op
        self.engine = engine
        self.dims = dims
        self.build = build
        self.in_shapes = in_shapes
        self.out_shapes = out_shapes
        self.block = block
        self.name = f"{engine}_{op}_" + "x".join(str(d) for d in dims)

    def manifest_line(self) -> str:
        fmt = lambda shapes: ";".join(  # noqa: E731
            "x".join(str(d) for d in s) for s in shapes
        )
        return (
            f"name={self.name} op={self.op} engine={self.engine} dtype=f64 "
            f"dims={','.join(str(d) for d in self.dims)} "
            f"inputs={fmt(self.in_shapes)} outputs={fmt(self.out_shapes)}"
        )


def gemm_spec(variant, t, engine, block=128):
    m = n = k = t
    a = (k, m) if variant == "tn" else (m, k)
    b = (n, k) if variant == "nt" else (k, n)
    return Spec(
        op=f"gemm_{variant}", engine=engine, dims=(m, n, k),
        build=lambda: model.make_gemm(m, n, k, variant=variant,
                                      engine=engine, block=block),
        in_shapes=[(m, n), a, b], out_shapes=[(m, n)], block=block,
    )


def gram_spec(m, k, c, engine, block=128):
    return Spec(
        op="gram_matvec", engine=engine, dims=(m, k, c),
        build=lambda: model.make_gram_matvec(m, k, c, engine=engine,
                                             block=block),
        in_shapes=[(m, k), (k, c), (1, 1)], out_shapes=[(k, c)], block=block,
    )


def rff_expand_spec(m, k0, d, engine, block=128):
    return Spec(
        op="rff_expand", engine=engine, dims=(m, k0, d),
        build=lambda: model.make_rff_expand(m, k0, d, engine=engine,
                                            block=block),
        in_shapes=[(m, k0), (k0, d), (1, d), (1, 1)], out_shapes=[(m, d)],
        block=block,
    )


def cg_update_spec(m, n, engine, block=128):
    return Spec(
        op="cg_update", engine=engine, dims=(m, n),
        build=lambda: model.make_cg_update(m, n, engine=engine, block=block),
        in_shapes=[(m, n)] * 4 + [(1, n)],
        out_shapes=[(m, n), (m, n)], block=block,
    )


def default_specs(quick: bool = False):
    """The artifact set DESIGN.md §3 lists; ``--quick`` trims to the shapes
    the python test-suite needs so pytest doesn't pay the full build."""
    specs = []
    # Composable square GEMM tiles (both engines; 3 sizes for the tile-size
    # ablation bench).
    tiles = [256] if quick else [128, 256, 512]
    for t in tiles:
        for variant in ("nn", "tn", "nt"):
            for engine in ("pallas", "xla"):
                specs.append(gemm_spec(variant, t, engine))
    # Gram-operator panels: m = row-panel, k = feature width, c = RHS block.
    gram_shapes = [(2048, 1024, 32)] if quick else [
        # CG speech problem: c=32 classes, feature sweep (Table 4)
        (2048, 512, 32), (2048, 1024, 32), (2048, 2048, 32), (2048, 3072, 32),
        # Lanczos SVD: single Lanczos vector (c=1 avoids 8x padding waste —
        # §Perf), plus c=8 for small blocks (Table 5 / Fig 3)
        (2048, 1024, 8), (2048, 2048, 8),
        # m=1024 variants: halve row-padding waste for small per-worker
        # shards (§Perf)
        (1024, 512, 32), (1024, 1024, 32), (1024, 2048, 32), (1024, 3072, 32),
    ]
    # Lanczos (c=1) panel grid: fine m granularity keeps row-padding waste
    # ≤2x even for tiny per-worker shards in the Fig-3 weak-scaling sweep
    # (§Perf); k covers the column-replication ladder 256..4096.
    if not quick:
        for m in (256, 512, 1024, 2048):
            for k in (256, 512, 1024, 2048, 4096):
                gram_shapes.append((m, k, 1))
    for (m, k, c) in gram_shapes:
        specs.append(gram_spec(m, k, c, "xla"))
    # pallas variants of the two default hot shapes (engine ablation)
    pallas_gram = [(2048, 1024, 32)] if quick else [(2048, 1024, 32),
                                                    (2048, 2048, 8)]
    for (m, k, c) in pallas_gram:
        specs.append(gram_spec(m, k, c, "pallas"))
    # Random-feature expansion panel (d chunked at 1024 by the rust side).
    for engine in ("pallas", "xla"):
        specs.append(rff_expand_spec(2048, 512, 1024, engine))
    # Fused CG state update, D chunked at 1024.
    for engine in ("pallas", "xla"):
        specs.append(cg_update_spec(1024, 32, engine))
    return specs


def lower_spec(spec: Spec) -> str:
    fn = spec.build()
    args = [jax.ShapeDtypeStruct(s, F64) for s in spec.in_shapes]
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the shapes the python tests need")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name filter")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    specs = default_specs(quick=args.quick)
    if args.only:
        keep = set(args.only.split(","))
        specs = [s for s in specs if s.name in keep]

    lines = []
    for i, spec in enumerate(specs):
        text = lower_spec(spec)
        path = os.path.join(args.out_dir, f"{spec.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:12]
        lines.append(spec.manifest_line() + f" sha={digest}")
        print(f"[{i + 1}/{len(specs)}] {spec.name}: "
              f"{len(text)} chars sha={digest}", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("# alchemist AOT artifact manifest (see compile/aot.py)\n")
        f.write("\n".join(lines) + "\n")
    print(f"wrote {len(specs)} artifacts to {args.out_dir}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
