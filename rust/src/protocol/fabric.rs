//! Wire messages for the network rank fabric (protocol v8).
//!
//! Two new channels appear when worker ranks run as separate OS
//! processes (`alchemist worker --connect ...`; see `docs/fabric.md`):
//!
//! * the **work** socket between the coordinator and each worker process
//!   — attach handshake, task dispatch, mesh brokering, store management
//!   ([`WorkMsg`]); the coordinator is control-plane only on it;
//! * **mesh** sockets between worker ranks — the [`FabricFrame`]s a
//!   `collectives::netcomm::TcpComm` exchanges peer-to-peer. Data frames
//!   carry the payload as raw little-endian f64 bytes after a fixed
//!   17-byte header so the send leg can go out as a gathered `writev`
//!   (header + borrowed payload, no intermediate copy) and the receive
//!   leg can decode borrowed out of the link's reusable frame buffer.

use super::value::Params;
use super::wire::{ProtocolError, Reader, Writer};
use crate::collectives::PoisonCause;

/// Byte length of the fixed header preceding a [`FabricFrame::Data`]
/// payload on the wire: frame tag + epoch + message tag.
pub const FABRIC_DATA_HEADER_LEN: usize = 1 + 8 + 8;

/// Rank⇄rank mesh frames. `Data` decodes *borrowed* — the payload points
/// into the receive buffer (not necessarily 8-aligned, hence bytes) and
/// consumers copy exactly once into their destination `Vec<f64>` via
/// [`crate::protocol::wire::le_f64s_to_vec`].
///
/// Every data/poison frame is stamped with the sender's group *epoch*
/// (bumped by `TcpComm::reset` between tasks): a receiver drops frames
/// from past epochs, delivers the current one, and parks future ones —
/// so a straggler frame from a finished task can never satisfy a recv
/// of the next task.
#[derive(Debug, PartialEq)]
pub enum FabricFrame<'a> {
    /// First frame on a freshly connected mesh link: who is calling, for
    /// which group. Sent by the lower-ranked side's connector.
    Hello { session_id: u64, from_rank: u32 },
    /// One point-to-point message of a collective.
    Data { epoch: u64, tag: u64, payload: &'a [u8] },
    /// The sender poisoned a tag lane of its group — or the whole group
    /// when `lane == collectives::LANE_ALL` — so peers blocked in a recv
    /// wake with the root cause instead of a bare connection error.
    /// (Protocol v9: lane-scoped poison lets a hard cancel kill one
    /// task's collectives without touching a sibling task's lane.)
    Poison { epoch: u64, lane: u64, cause: PoisonCause },
    /// Orderly teardown: the sender is closing this link on purpose, so
    /// the EOF that follows must not be treated as a rank failure.
    Close,
}

fn encode_poison(w: &mut Writer, cause: PoisonCause) {
    match cause {
        PoisonCause::RankFailed(rank) => {
            w.u8(0);
            w.u64(rank as u64);
        }
        PoisonCause::HardCancel => w.u8(1),
    }
}

fn decode_poison(r: &mut Reader) -> Result<PoisonCause, ProtocolError> {
    Ok(match r.u8()? {
        0 => PoisonCause::RankFailed(r.u64()? as usize),
        1 => PoisonCause::HardCancel,
        tag => return Err(ProtocolError::BadTag { tag, what: "PoisonCause" }),
    })
}

impl<'a> FabricFrame<'a> {
    /// Encode the non-payload frames. `Data` never goes through here —
    /// its header comes from [`fabric_data_header`] and its payload bytes
    /// are written (or `writev`'d) straight from the `Vec<f64>`.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            FabricFrame::Hello { session_id, from_rank } => {
                w.u8(1);
                w.u64(*session_id);
                w.u32(*from_rank);
            }
            FabricFrame::Data { epoch, tag, payload } => {
                w.u8(2);
                w.u64(*epoch);
                w.u64(*tag);
                w.raw_bytes(payload);
            }
            FabricFrame::Poison { epoch, lane, cause } => {
                w.u8(3);
                w.u64(*epoch);
                w.u64(*lane);
                encode_poison(&mut w, *cause);
            }
            FabricFrame::Close => w.u8(4),
        }
        w.into_bytes()
    }

    pub fn decode(buf: &'a [u8]) -> Result<Self, ProtocolError> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            1 => FabricFrame::Hello { session_id: r.u64()?, from_rank: r.u32()? },
            2 => {
                let epoch = r.u64()?;
                let tag = r.u64()?;
                // the payload is the entire rest of the frame (its length
                // is implied by the frame length — no redundant count)
                let payload = r.raw_bytes(r.remaining())?;
                FabricFrame::Data { epoch, tag, payload }
            }
            3 => FabricFrame::Poison {
                epoch: r.u64()?,
                lane: r.u64()?,
                cause: decode_poison(&mut r)?,
            },
            4 => FabricFrame::Close,
            tag => return Err(ProtocolError::BadTag { tag, what: "FabricFrame" }),
        };
        r.finish()?;
        Ok(msg)
    }
}

/// The fixed-size header of a [`FabricFrame::Data`]; callers append the
/// payload's raw little-endian f64 bytes (buffered for eager messages,
/// gathered `writev` for rendezvous-size ones).
pub fn fabric_data_header(epoch: u64, tag: u64) -> [u8; FABRIC_DATA_HEADER_LEN] {
    let mut h = [0u8; FABRIC_DATA_HEADER_LEN];
    h[0] = 2;
    h[1..9].copy_from_slice(&epoch.to_le_bytes());
    h[9..17].copy_from_slice(&tag.to_le_bytes());
    h
}

/// Shape of one task output a worker process reports back in
/// [`WorkMsg::TaskDone`]: everything the coordinator needs to build the
/// client-visible handle without reaching into the worker's store.
#[derive(Debug, Clone, PartialEq)]
pub struct WireOutput {
    pub id: u64,
    pub name: String,
    pub rows: u64,
    pub cols: u64,
    /// Row range owned by each group rank: `[start, end)`.
    pub ranges: Vec<(u64, u64)>,
}

impl WireOutput {
    fn encode(&self, w: &mut Writer) {
        w.u64(self.id);
        w.str(&self.name);
        w.u64(self.rows);
        w.u64(self.cols);
        encode_ranges(w, &self.ranges);
    }

    fn decode(r: &mut Reader) -> Result<Self, ProtocolError> {
        Ok(WireOutput {
            id: r.u64()?,
            name: r.str()?,
            rows: r.u64()?,
            cols: r.u64()?,
            ranges: decode_ranges(r)?,
        })
    }
}

fn encode_ranges(w: &mut Writer, ranges: &[(u64, u64)]) {
    w.u32(ranges.len() as u32);
    for (a, b) in ranges {
        w.u64(*a);
        w.u64(*b);
    }
}

fn decode_ranges(r: &mut Reader) -> Result<Vec<(u64, u64)>, ProtocolError> {
    let n = r.u32()?;
    (0..n).map(|_| Ok((r.u64()?, r.u64()?))).collect()
}

fn encode_timings(w: &mut Writer, timings: &[(String, f64)]) {
    w.u32(timings.len() as u32);
    for (name, secs) in timings {
        w.str(name);
        w.f64(*secs);
    }
}

fn decode_timings(r: &mut Reader) -> Result<Vec<(String, f64)>, ProtocolError> {
    let n = r.u32()?;
    (0..n)
        .map(|_| Ok((r.str()?, r.f64()?)))
        .collect::<Result<_, ProtocolError>>()
}

/// How a remote rank's task failed, preserved across the wire so the
/// coordinator's root-cause-first aggregation sees the same
/// `CommError` classification it would for an in-process rank.
pub const FAIL_KIND_PLAIN: u8 = 0;
pub const FAIL_KIND_PEER_FAILED: u8 = 1;
pub const FAIL_KIND_CANCELLED: u8 = 2;
pub const FAIL_KIND_TIMEOUT: u8 = 3;

/// Coordinator⇄worker-process control messages (the "work" socket). One
/// long-lived connection per worker process; the coordinator multiplexes
/// requests by `req_id` and the worker answers each with `TaskDone` /
/// `TaskFailed` / `Ack` carrying the same id (replies may arrive out of
/// order — a task runs while store ops are serviced).
#[derive(Debug, Clone, PartialEq)]
pub enum WorkMsg {
    // worker -> coordinator
    /// First message after connect: which rank this process is, where its
    /// data-plane and mesh listeners ended up binding.
    Attach { version: u32, rank: u32, data_addr: String, mesh_addr: String },
    /// Task finished on this rank; `outputs` describe what landed in the
    /// worker's local store.
    TaskDone {
        req_id: u64,
        outputs: Vec<WireOutput>,
        scalars: Params,
        /// Named timing laps measured on the worker (compute, ...).
        timings: Vec<(String, f64)>,
    },
    /// Task failed on this rank; `kind` is one of the `FAIL_KIND_*`
    /// constants so the coordinator can rebuild the `CommError` (and its
    /// collateral-vs-root-cause classification) exactly.
    TaskFailed { req_id: u64, kind: u8, rank: u64, tag: u64, message: String },
    /// Generic reply to mesh/store/session requests. `value` carries the
    /// operation's scalar result (rows sealed, bytes freed, ...), 0 when
    /// there is none.
    Ack { req_id: u64, ok: bool, value: u64, message: String },

    // coordinator -> worker
    AttachAck { rank: u32 },
    RunTask {
        req_id: u64,
        session_id: u64,
        task_id: u64,
        /// Builtin library identity (`Library::name()`), not the
        /// client-chosen registration alias — the worker process resolves
        /// it through `registry::builtin`.
        lib: String,
        routine: String,
        params: Params,
        /// Validated output-id reservation for this task (see
        /// `docs/tasks.md`): outputs must use ids in
        /// `[out_base, out_base + out_span)`.
        out_base: u64,
        out_span: u64,
        /// Engine thread-pool lease for this rank during the task.
        engine_threads: u32,
        /// The task's tag lane in the group communicator (protocol v9):
        /// the worker wraps the session fabric in a `LaneComm` at
        /// `lane << LANE_SHIFT` so concurrent tasks' collectives never
        /// collide. Monotonic per session, never reused; 0 is reserved
        /// for untasked traffic.
        lane: u64,
    },
    /// Cooperative cancellation of a running task (the remote half of the
    /// coordinator's cancel token). Fire-and-forget: no reply — the task
    /// itself answers with `TaskFailed("task cancelled")`.
    CancelTask { session_id: u64, task_id: u64 },
    /// Form the session's rank mesh: connect/accept until this worker has
    /// a live link to every peer in `peers` (index = group rank; its own
    /// entry is ignored). Acked when the mesh is fully connected.
    MeshForm { req_id: u64, session_id: u64, group_rank: u32, peers: Vec<String> },
    /// Reset the session's communicator between tasks (epoch bump; drops
    /// stragglers, clears poison). Acked.
    MeshReset { req_id: u64, session_id: u64 },
    /// Poison the session's communicator (hard cancel escalation or a
    /// peer process dying) — one tag lane when `lane` names a task's
    /// lane, the whole group when `lane == collectives::LANE_ALL`.
    /// Fire-and-forget — the coordinator may be telling a wedged worker
    /// whose ack would never come.
    MeshPoison { session_id: u64, kind: u8, rank: u64, lane: u64 },
    /// Retire a finished task's tag lane (protocol v9): drop queued and
    /// in-flight frames for the lane and clear its lane poison.
    /// Fire-and-forget — per-work-socket FIFO orders it before the next
    /// `RunTask`, so the worker never sees a new task before the old
    /// lane's bookkeeping is gone.
    MeshRetire { session_id: u64, lane: u64 },
    /// Tear down the session on this worker: drop its communicator and
    /// free its namespaced blocks. Acked with the freed block count.
    SessionClose { req_id: u64, session_id: u64 },
    /// Allocate an ingest block in the worker's store (the remote half of
    /// `CreateMatrix`). `ranges` is the full group layout; `slot` is this
    /// worker's index into it. Acked.
    StoreAlloc {
        req_id: u64,
        session_id: u64,
        id: u64,
        name: String,
        rows: u64,
        cols: u64,
        ranges: Vec<(u64, u64)>,
        slot: u32,
    },
    /// Seal an ingest block; acked with the rows this rank received.
    StoreSeal { req_id: u64, id: u64 },
    /// Free a block (rollback / client free). Fire-and-forget.
    StoreFree { id: u64 },
    /// Map (or read) this worker's shard of an `hdf5sim` file at `path`
    /// on the worker's filesystem — the remote half of `LoadMatrix`.
    /// Acked.
    StoreLoad {
        req_id: u64,
        session_id: u64,
        id: u64,
        name: String,
        path: String,
        rows: u64,
        cols: u64,
        ranges: Vec<(u64, u64)>,
        slot: u32,
    },
    /// Exit the worker process after draining. Fire-and-forget.
    Shutdown,
    /// Snapshot the worker's store occupancy (protocol v10, the remote
    /// half of the chaos harness's leak accounting): acked with
    /// `value = (blocks << 32) | spill_segments`, each saturated at
    /// `u32::MAX` (real counts are tiny; the packing exists because
    /// `Ack` carries one scalar).
    StoreStats { req_id: u64 },
    /// Replay a dead rank's shard checkpoint onto this (replacement)
    /// worker (protocol v10, `docs/recovery.md`): read the `hdf5sim`
    /// file at `path` — the dead rank's task-boundary snapshot — and
    /// register it as an already-sealed block with the dead rank's
    /// layout slot. Same field meaning as `StoreLoad`, but the file
    /// holds ONLY this slot's rows (the checkpoint is per-shard), so
    /// the worker reads it whole instead of slicing its range. Acked
    /// with the restored local row count.
    StoreRestore {
        req_id: u64,
        session_id: u64,
        id: u64,
        name: String,
        path: String,
        rows: u64,
        cols: u64,
        ranges: Vec<(u64, u64)>,
        slot: u32,
    },
}

impl WorkMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            WorkMsg::Attach { version, rank, data_addr, mesh_addr } => {
                w.u8(0);
                w.u32(*version);
                w.u32(*rank);
                w.str(data_addr);
                w.str(mesh_addr);
            }
            WorkMsg::TaskDone { req_id, outputs, scalars, timings } => {
                w.u8(1);
                w.u64(*req_id);
                w.u32(outputs.len() as u32);
                for o in outputs {
                    o.encode(&mut w);
                }
                scalars.encode(&mut w);
                encode_timings(&mut w, timings);
            }
            WorkMsg::TaskFailed { req_id, kind, rank, tag, message } => {
                w.u8(2);
                w.u64(*req_id);
                w.u8(*kind);
                w.u64(*rank);
                w.u64(*tag);
                w.str(message);
            }
            WorkMsg::Ack { req_id, ok, value, message } => {
                w.u8(3);
                w.u64(*req_id);
                w.bool(*ok);
                w.u64(*value);
                w.str(message);
            }
            WorkMsg::AttachAck { rank } => {
                w.u8(128);
                w.u32(*rank);
            }
            WorkMsg::RunTask {
                req_id,
                session_id,
                task_id,
                lib,
                routine,
                params,
                out_base,
                out_span,
                engine_threads,
                lane,
            } => {
                w.u8(129);
                w.u64(*req_id);
                w.u64(*session_id);
                w.u64(*task_id);
                w.str(lib);
                w.str(routine);
                params.encode(&mut w);
                w.u64(*out_base);
                w.u64(*out_span);
                w.u32(*engine_threads);
                w.u64(*lane);
            }
            WorkMsg::CancelTask { session_id, task_id } => {
                w.u8(130);
                w.u64(*session_id);
                w.u64(*task_id);
            }
            WorkMsg::MeshForm { req_id, session_id, group_rank, peers } => {
                w.u8(131);
                w.u64(*req_id);
                w.u64(*session_id);
                w.u32(*group_rank);
                w.u32(peers.len() as u32);
                for p in peers {
                    w.str(p);
                }
            }
            WorkMsg::MeshReset { req_id, session_id } => {
                w.u8(132);
                w.u64(*req_id);
                w.u64(*session_id);
            }
            WorkMsg::MeshPoison { session_id, kind, rank, lane } => {
                w.u8(133);
                w.u64(*session_id);
                w.u8(*kind);
                w.u64(*rank);
                w.u64(*lane);
            }
            WorkMsg::MeshRetire { session_id, lane } => {
                w.u8(140);
                w.u64(*session_id);
                w.u64(*lane);
            }
            WorkMsg::SessionClose { req_id, session_id } => {
                w.u8(134);
                w.u64(*req_id);
                w.u64(*session_id);
            }
            WorkMsg::StoreAlloc {
                req_id,
                session_id,
                id,
                name,
                rows,
                cols,
                ranges,
                slot,
            } => {
                w.u8(135);
                w.u64(*req_id);
                w.u64(*session_id);
                w.u64(*id);
                w.str(name);
                w.u64(*rows);
                w.u64(*cols);
                encode_ranges(&mut w, ranges);
                w.u32(*slot);
            }
            WorkMsg::StoreSeal { req_id, id } => {
                w.u8(136);
                w.u64(*req_id);
                w.u64(*id);
            }
            WorkMsg::StoreFree { id } => {
                w.u8(137);
                w.u64(*id);
            }
            WorkMsg::StoreLoad {
                req_id,
                session_id,
                id,
                name,
                path,
                rows,
                cols,
                ranges,
                slot,
            } => {
                w.u8(138);
                w.u64(*req_id);
                w.u64(*session_id);
                w.u64(*id);
                w.str(name);
                w.str(path);
                w.u64(*rows);
                w.u64(*cols);
                encode_ranges(&mut w, ranges);
                w.u32(*slot);
            }
            WorkMsg::Shutdown => w.u8(139),
            WorkMsg::StoreStats { req_id } => {
                w.u8(141);
                w.u64(*req_id);
            }
            WorkMsg::StoreRestore {
                req_id,
                session_id,
                id,
                name,
                path,
                rows,
                cols,
                ranges,
                slot,
            } => {
                w.u8(142);
                w.u64(*req_id);
                w.u64(*session_id);
                w.u64(*id);
                w.str(name);
                w.str(path);
                w.u64(*rows);
                w.u64(*cols);
                encode_ranges(&mut w, ranges);
                w.u32(*slot);
            }
        }
        w.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<Self, ProtocolError> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            0 => WorkMsg::Attach {
                version: r.u32()?,
                rank: r.u32()?,
                data_addr: r.str()?,
                mesh_addr: r.str()?,
            },
            1 => {
                let req_id = r.u64()?;
                let n = r.u32()?;
                let outputs = (0..n)
                    .map(|_| WireOutput::decode(&mut r))
                    .collect::<Result<_, _>>()?;
                let scalars = Params::decode(&mut r)?;
                let timings = decode_timings(&mut r)?;
                WorkMsg::TaskDone { req_id, outputs, scalars, timings }
            }
            2 => WorkMsg::TaskFailed {
                req_id: r.u64()?,
                kind: r.u8()?,
                rank: r.u64()?,
                tag: r.u64()?,
                message: r.str()?,
            },
            3 => WorkMsg::Ack {
                req_id: r.u64()?,
                ok: r.bool()?,
                value: r.u64()?,
                message: r.str()?,
            },
            128 => WorkMsg::AttachAck { rank: r.u32()? },
            129 => WorkMsg::RunTask {
                req_id: r.u64()?,
                session_id: r.u64()?,
                task_id: r.u64()?,
                lib: r.str()?,
                routine: r.str()?,
                params: Params::decode(&mut r)?,
                out_base: r.u64()?,
                out_span: r.u64()?,
                engine_threads: r.u32()?,
                lane: r.u64()?,
            },
            130 => WorkMsg::CancelTask { session_id: r.u64()?, task_id: r.u64()? },
            131 => {
                let req_id = r.u64()?;
                let session_id = r.u64()?;
                let group_rank = r.u32()?;
                let n = r.u32()?;
                let peers = (0..n).map(|_| r.str()).collect::<Result<_, _>>()?;
                WorkMsg::MeshForm { req_id, session_id, group_rank, peers }
            }
            132 => WorkMsg::MeshReset { req_id: r.u64()?, session_id: r.u64()? },
            133 => WorkMsg::MeshPoison {
                session_id: r.u64()?,
                kind: r.u8()?,
                rank: r.u64()?,
                lane: r.u64()?,
            },
            140 => WorkMsg::MeshRetire { session_id: r.u64()?, lane: r.u64()? },
            134 => WorkMsg::SessionClose { req_id: r.u64()?, session_id: r.u64()? },
            135 => WorkMsg::StoreAlloc {
                req_id: r.u64()?,
                session_id: r.u64()?,
                id: r.u64()?,
                name: r.str()?,
                rows: r.u64()?,
                cols: r.u64()?,
                ranges: decode_ranges(&mut r)?,
                slot: r.u32()?,
            },
            136 => WorkMsg::StoreSeal { req_id: r.u64()?, id: r.u64()? },
            137 => WorkMsg::StoreFree { id: r.u64()? },
            138 => WorkMsg::StoreLoad {
                req_id: r.u64()?,
                session_id: r.u64()?,
                id: r.u64()?,
                name: r.str()?,
                path: r.str()?,
                rows: r.u64()?,
                cols: r.u64()?,
                ranges: decode_ranges(&mut r)?,
                slot: r.u32()?,
            },
            139 => WorkMsg::Shutdown,
            141 => WorkMsg::StoreStats { req_id: r.u64()? },
            142 => WorkMsg::StoreRestore {
                req_id: r.u64()?,
                session_id: r.u64()?,
                id: r.u64()?,
                name: r.str()?,
                path: r.str()?,
                rows: r.u64()?,
                cols: r.u64()?,
                ranges: decode_ranges(&mut r)?,
                slot: r.u32()?,
            },
            tag => return Err(ProtocolError::BadTag { tag, what: "WorkMsg" }),
        };
        r.finish()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fabric_frame_roundtrip() {
        let payload = [1u8, 2, 3, 4, 5, 6, 7, 8];
        let frames = vec![
            FabricFrame::Hello { session_id: 9, from_rank: 2 },
            FabricFrame::Data { epoch: 3, tag: 0x4347_0000, payload: &payload },
            FabricFrame::Data { epoch: 0, tag: 7, payload: &[] },
            FabricFrame::Poison {
                epoch: 3,
                lane: crate::collectives::LANE_ALL,
                cause: PoisonCause::RankFailed(2),
            },
            FabricFrame::Poison { epoch: 0, lane: 7, cause: PoisonCause::HardCancel },
            FabricFrame::Close,
        ];
        for f in frames {
            let buf = f.encode();
            assert_eq!(FabricFrame::decode(&buf).unwrap(), f);
        }
    }

    #[test]
    fn data_header_matches_encoded_frame() {
        // the writev send path emits header + raw payload bytes; that
        // must be byte-identical to the buffered encode
        let payload = 1.5f64.to_le_bytes();
        let frame = FabricFrame::Data { epoch: 11, tag: 42, payload: &payload };
        let buf = frame.encode();
        let header = fabric_data_header(11, 42);
        assert_eq!(&buf[..FABRIC_DATA_HEADER_LEN], &header[..]);
        assert_eq!(&buf[FABRIC_DATA_HEADER_LEN..], &payload[..]);
    }

    #[test]
    fn work_msg_roundtrip_all_variants() {
        let msgs = vec![
            WorkMsg::Attach {
                version: 8,
                rank: 1,
                data_addr: "127.0.0.1:4001".into(),
                mesh_addr: "127.0.0.1:4101".into(),
            },
            WorkMsg::TaskDone {
                req_id: 5,
                outputs: vec![WireOutput {
                    id: 100,
                    name: "W".into(),
                    rows: 8,
                    cols: 4,
                    ranges: vec![(0, 4), (4, 8)],
                }],
                scalars: Params::new().with_i64("iters", 37),
                timings: vec![("compute".into(), 1.5)],
            },
            WorkMsg::TaskFailed {
                req_id: 5,
                kind: FAIL_KIND_PEER_FAILED,
                rank: 2,
                tag: 0,
                message: "collective aborted: peer rank 2 failed".into(),
            },
            WorkMsg::TaskFailed {
                req_id: 6,
                kind: FAIL_KIND_TIMEOUT,
                rank: 1,
                tag: 0x4347_0000,
                message: "recv deadline expired".into(),
            },
            WorkMsg::Ack { req_id: 7, ok: true, value: 128, message: String::new() },
            WorkMsg::Ack { req_id: 8, ok: false, value: 0, message: "boom".into() },
            WorkMsg::AttachAck { rank: 1 },
            WorkMsg::RunTask {
                req_id: 9,
                session_id: 3,
                task_id: 12,
                lib: "skylark".into(),
                routine: "cg_solve".into(),
                params: Params::new().with_f64("lambda", 1e-5).with_matrix("X", 3),
                out_base: 1000,
                out_span: 8,
                engine_threads: 2,
                lane: 3,
            },
            WorkMsg::CancelTask { session_id: 3, task_id: 12 },
            WorkMsg::MeshForm {
                req_id: 10,
                session_id: 3,
                group_rank: 1,
                peers: vec!["127.0.0.1:4101".into(), "127.0.0.1:4102".into()],
            },
            WorkMsg::MeshReset { req_id: 11, session_id: 3 },
            WorkMsg::MeshPoison {
                session_id: 3,
                kind: 0,
                rank: 2,
                lane: crate::collectives::LANE_ALL,
            },
            WorkMsg::MeshRetire { session_id: 3, lane: 4 },
            WorkMsg::SessionClose { req_id: 12, session_id: 3 },
            WorkMsg::StoreAlloc {
                req_id: 13,
                session_id: 3,
                id: 200,
                name: "X".into(),
                rows: 10,
                cols: 4,
                ranges: vec![(0, 5), (5, 10)],
                slot: 1,
            },
            WorkMsg::StoreSeal { req_id: 14, id: 200 },
            WorkMsg::StoreFree { id: 200 },
            WorkMsg::StoreLoad {
                req_id: 15,
                session_id: 3,
                id: 201,
                name: "ocean".into(),
                path: "/data/ocean.h5sim".into(),
                rows: 100,
                cols: 8,
                ranges: vec![(0, 50), (50, 100)],
                slot: 0,
            },
            WorkMsg::Shutdown,
            WorkMsg::StoreStats { req_id: 16 },
            WorkMsg::StoreRestore {
                req_id: 17,
                session_id: 3,
                id: 202,
                name: "X".into(),
                path: "/tmp/ckpt/alchemist-ckpt-s3-m202-slot1.h5sim".into(),
                rows: 10,
                cols: 4,
                ranges: vec![(0, 5), (5, 10)],
                slot: 1,
            },
        ];
        for m in msgs {
            let buf = m.encode();
            assert_eq!(WorkMsg::decode(&buf).unwrap(), m);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(WorkMsg::decode(&[250]).is_err());
        assert!(FabricFrame::decode(&[]).is_err());
        assert!(FabricFrame::decode(&[9]).is_err());
        // trailing bytes after a Close
        assert!(FabricFrame::decode(&[4, 0]).is_err());
        // truncated Poison
        let buf = FabricFrame::Poison {
            epoch: 1,
            lane: crate::collectives::LANE_ALL,
            cause: PoisonCause::RankFailed(0),
        }
        .encode();
        assert!(FabricFrame::decode(&buf[..buf.len() - 1]).is_err());
    }
}
