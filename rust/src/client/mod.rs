//! The Alchemist-Client Interface (paper §3.1.2) — what the Spark-side
//! application imports.
//!
//! Mirrors the Figure 2 API: an [`AlchemistContext`] created against a
//! running server, `register_library`, matrix send (→ [`AlMatrix`] proxy),
//! `run_task`, and `to_indexed_row_matrix` to materialize results back on
//! the client. Distributed payloads move over per-executor TCP sockets to
//! the workers; only metadata crosses the driver connection.
//!
//! Protocol v4 adds the asynchronous task API: `submit` returns a
//! [`TaskHandle`] with `status()` / `wait()` / `cancel()`, and `run_task`
//! is submit + wait (see `docs/tasks.md`).
//!
//! Protocol v9 adds serving-grade scheduling: `connect_with_priority`
//! requests an admission class, and
//! [`AlchemistContext::subscribe_metrics`] opens a push-based
//! [`MetricsStream`] of scheduler snapshots (see `docs/scheduler.md`).

pub mod almatrix;
pub mod context;
pub mod transfer;

pub use almatrix::AlMatrix;
pub use context::{
    AlchemistContext, MetricsStream, MetricsUpdate, TaskHandle, TaskResult,
};
pub use transfer::TransferStats;
