//! Typed parameters for library routines (paper §3.1.3: "the name of the
//! routine ... as well as the serialized input parameters").

use std::collections::BTreeMap;

use super::wire::{ProtocolError, Reader, Writer};

/// A routine input/output value. `Matrix` carries a matrix-handle id — the
/// paper's `AlMatrix` proxies travel through `Params` so routine outputs
/// can feed the next routine without leaving the server.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    I64(i64),
    F64(f64),
    Bool(bool),
    Str(String),
    Matrix(u64),
    F64s(Vec<f64>),
}

impl Value {
    fn tag(&self) -> u8 {
        match self {
            Value::I64(_) => 0,
            Value::F64(_) => 1,
            Value::Bool(_) => 2,
            Value::Str(_) => 3,
            Value::Matrix(_) => 4,
            Value::F64s(_) => 5,
        }
    }

    pub fn encode(&self, w: &mut Writer) {
        w.u8(self.tag());
        match self {
            Value::I64(v) => w.i64(*v),
            Value::F64(v) => w.f64(*v),
            Value::Bool(v) => w.bool(*v),
            Value::Str(v) => w.str(v),
            Value::Matrix(v) => w.u64(*v),
            Value::F64s(v) => w.f64s(v),
        }
    }

    pub fn decode(r: &mut Reader) -> Result<Self, ProtocolError> {
        Ok(match r.u8()? {
            0 => Value::I64(r.i64()?),
            1 => Value::F64(r.f64()?),
            2 => Value::Bool(r.bool()?),
            3 => Value::Str(r.str()?),
            4 => Value::Matrix(r.u64()?),
            5 => Value::F64s(r.f64s()?),
            tag => return Err(ProtocolError::BadTag { tag, what: "Value" }),
        })
    }
}

/// Ordered string→value map with typed accessors.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Params(pub BTreeMap<String, Value>);

impl Params {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(mut self, key: &str, v: Value) -> Self {
        self.0.insert(key.to_string(), v);
        self
    }

    pub fn with_i64(self, key: &str, v: i64) -> Self {
        self.set(key, Value::I64(v))
    }

    pub fn with_f64(self, key: &str, v: f64) -> Self {
        self.set(key, Value::F64(v))
    }

    pub fn with_str(self, key: &str, v: &str) -> Self {
        self.set(key, Value::Str(v.to_string()))
    }

    pub fn with_matrix(self, key: &str, id: u64) -> Self {
        self.set(key, Value::Matrix(id))
    }

    pub fn with_bool(self, key: &str, v: bool) -> Self {
        self.set(key, Value::Bool(v))
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.0.get(key)
    }

    pub fn i64(&self, key: &str) -> crate::Result<i64> {
        match self.get(key) {
            Some(Value::I64(v)) => Ok(*v),
            other => anyhow::bail!("param {key:?}: expected i64, got {other:?}"),
        }
    }

    pub fn i64_or(&self, key: &str, default: i64) -> crate::Result<i64> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::I64(v)) => Ok(*v),
            other => anyhow::bail!("param {key:?}: expected i64, got {other:?}"),
        }
    }

    pub fn f64(&self, key: &str) -> crate::Result<f64> {
        match self.get(key) {
            Some(Value::F64(v)) => Ok(*v),
            Some(Value::I64(v)) => Ok(*v as f64),
            other => anyhow::bail!("param {key:?}: expected f64, got {other:?}"),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> crate::Result<f64> {
        if self.get(key).is_none() {
            return Ok(default);
        }
        self.f64(key)
    }

    pub fn str(&self, key: &str) -> crate::Result<&str> {
        match self.get(key) {
            Some(Value::Str(v)) => Ok(v),
            other => anyhow::bail!("param {key:?}: expected str, got {other:?}"),
        }
    }

    pub fn matrix(&self, key: &str) -> crate::Result<u64> {
        match self.get(key) {
            Some(Value::Matrix(v)) => Ok(*v),
            other => anyhow::bail!("param {key:?}: expected matrix handle, got {other:?}"),
        }
    }

    pub fn encode(&self, w: &mut Writer) {
        w.u32(self.0.len() as u32);
        for (k, v) in &self.0 {
            w.str(k);
            v.encode(w);
        }
    }

    pub fn decode(r: &mut Reader) -> Result<Self, ProtocolError> {
        let n = r.u32()?;
        let mut map = BTreeMap::new();
        for _ in 0..n {
            let k = r.str()?;
            let v = Value::decode(r)?;
            map.insert(k, v);
        }
        Ok(Params(map))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_roundtrip() {
        let p = Params::new()
            .with_i64("iters", 100)
            .with_f64("lambda", 1e-5)
            .with_str("mode", "cg")
            .with_matrix("X", 3)
            .with_bool("verbose", true)
            .set("v", Value::F64s(vec![1.0, 2.0]));
        let mut w = Writer::new();
        p.encode(&mut w);
        let buf = w.into_bytes();
        let mut r = Reader::new(&buf);
        let q = Params::decode(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn typed_accessors_enforce_types() {
        let p = Params::new().with_i64("n", 5).with_f64("x", 1.5);
        assert_eq!(p.i64("n").unwrap(), 5);
        assert_eq!(p.f64("x").unwrap(), 1.5);
        assert_eq!(p.f64("n").unwrap(), 5.0); // widening ok
        assert!(p.i64("x").is_err());
        assert!(p.str("n").is_err());
        assert!(p.matrix("missing").is_err());
        assert_eq!(p.i64_or("missing", 9).unwrap(), 9);
        assert_eq!(p.f64_or("missing", 0.5).unwrap(), 0.5);
    }
}
