//! Ablations over the design choices DESIGN.md §7 calls out:
//!
//! 1. engine on the worker hot path (native / xla / pallas-interpret)
//! 2. GEMM tile size (128 / 256 / 512)
//! 3. transfer row-batching (rows per frame)
//! 4. overhead-model sensitivity (scheduler delay ×{0.25, 1, 4})

mod bench_common;

use alchemist::cli::Args;
use alchemist::compute::{build_engine, Engine, GemmVariant};
use alchemist::config::Config;
use alchemist::coordinator::AlchemistServer;
use alchemist::client::AlchemistContext;
use alchemist::distmat::LocalMatrix;
use alchemist::linalg::CgOptions;
use alchemist::metrics::{Stats, Table};
use alchemist::sparklite::{mllib, IndexedRowMatrix, SparkEngine};
use alchemist::util::prng::Rng;
use alchemist::util::timer::time;
use bench_common::{bench_config, is_quick, require_artifacts};

fn random(seed: u64, r: usize, c: usize) -> LocalMatrix {
    let mut rng = Rng::new(seed);
    LocalMatrix::from_fn(r, c, |_, _| rng.normal())
}

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let cfg = bench_config(&args)?;
    if !require_artifacts(&cfg) {
        return Ok(());
    }
    let quick = is_quick(&args);

    engine_ablation(&cfg, quick)?;
    tile_ablation(&cfg, quick)?;
    frame_ablation(&cfg, quick)?;
    overhead_ablation(&cfg, quick)?;
    Ok(())
}

/// #1: same gram-matvec workload on each engine.
fn engine_ablation(base: &Config, quick: bool) -> alchemist::Result<()> {
    let rows = if quick { 2048 } else { 4096 };
    let k = 1024;
    let c = 32;
    let reps = if quick { 2 } else { 4 };
    let a = random(1, rows, k);
    let v = random(2, k, c);

    let mut table = Table::new(
        &format!("Ablation 1: engine on the hot path (gram_matvec {rows}x{k}x{c})"),
        &["engine", "secs/op (mean±sd)", "GFLOP/s", "pjrt calls/op"],
    );
    for engine_name in ["native", "xla", "xla+cache", "pallas"] {
        let mut cfg = base.clone();
        let keyed = engine_name == "xla+cache";
        cfg.apply("engine", if keyed { "xla" } else { engine_name })?;
        let mut engine: Box<dyn Engine> = build_engine(&cfg)?;
        let key = alchemist::compute::fresh_operand_key();
        // warmup (compiles executables; for the keyed row also uploads A)
        if keyed {
            engine.gram_matvec_keyed(key, &a, &v, 0.1)?;
        } else {
            engine.gram_matvec(&a, &v, 0.1)?;
        }
        let calls0 = engine.exec_stats().0;
        let mut stats = Stats::new();
        for _ in 0..reps {
            let (_, secs) = time(|| {
                if keyed {
                    engine.gram_matvec_keyed(key, &a, &v, 0.1).unwrap()
                } else {
                    engine.gram_matvec(&a, &v, 0.1).unwrap()
                }
            });
            stats.push(secs);
        }
        let flops = 4.0 * rows as f64 * k as f64 * c as f64;
        let calls_per_op =
            (engine.exec_stats().0 - calls0) as f64 / reps as f64;
        table.row(&[
            engine_name.into(),
            stats.mean_pm_std(4),
            format!("{:.2}", flops / stats.mean() / 1e9),
            format!("{calls_per_op:.0}"),
        ]);
    }
    table.print();
    Ok(())
}

/// #2: composed GEMM through each exported tile size.
fn tile_ablation(base: &Config, quick: bool) -> alchemist::Result<()> {
    let n = if quick { 512 } else { 1024 };
    let a = random(3, n, n);
    let b = random(4, n, n);
    let reps = if quick { 1 } else { 2 };

    let mut table = Table::new(
        &format!("Ablation 2: GEMM tile size ({n}^3 composed product, xla engine)"),
        &["tile", "secs (mean)", "GFLOP/s", "tiles executed"],
    );
    for tile in [128usize, 256, 512] {
        let mut cfg = base.clone();
        cfg.apply("engine", "xla")?;
        cfg.tile = tile;
        let mut engine = build_engine(&cfg)?;
        let mut c = LocalMatrix::zeros(n, n);
        engine.gemm(GemmVariant::NN, &mut c, &a, &b)?; // warmup/compile
        let calls0 = engine.exec_stats().0;
        let mut stats = Stats::new();
        for _ in 0..reps {
            let mut c = LocalMatrix::zeros(n, n);
            let (_, secs) = time(|| engine.gemm(GemmVariant::NN, &mut c, &a, &b).unwrap());
            stats.push(secs);
        }
        let flops = 2.0 * (n as f64).powi(3);
        table.row(&[
            tile.to_string(),
            format!("{:.4}", stats.mean()),
            format!("{:.2}", flops / stats.mean() / 1e9),
            format!("{}", (engine.exec_stats().0 - calls0) / reps as u64),
        ]);
    }
    table.print();
    Ok(())
}

/// #3: transfer rows-per-frame sweep.
fn frame_ablation(base: &Config, quick: bool) -> alchemist::Result<()> {
    let rows = if quick { 4096 } else { 8192 };
    let cols = 512;
    let data = random(5, rows, cols);
    let irm = IndexedRowMatrix::from_local(&data, 8);

    let mut table = Table::new(
        &format!("Ablation 3: transfer row batching ({rows}x{cols} push, 4 executors, 2 workers)"),
        &["rows/frame", "secs", "GB/s", "frames"],
    );
    for rpf in [1usize, 8, 64, 512] {
        let mut cfg = base.clone();
        cfg.apply("engine", "native")?;
        cfg.transfer.rows_per_frame = rpf;
        let server = AlchemistServer::start(cfg.clone(), 2)?;
        let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 4)?;
        let (al, stats) = ac.send_matrix("X", &irm)?;
        table.row(&[
            rpf.to_string(),
            format!("{:.3}", stats.secs),
            format!("{:.2}", stats.throughput_gbps()),
            stats.frames.to_string(),
        ]);
        ac.free(&al)?;
        ac.stop();
        server.shutdown();
    }
    table.print();
    println!("(paper ships one row at a time; batching is this repro's knob #3)");
    Ok(())
}

/// #4: Spark/Alchemist gap vs scheduler-delay scaling.
fn overhead_ablation(base: &Config, quick: bool) -> alchemist::Result<()> {
    let rows = if quick { 1024 } else { 2048 };
    let d = 512;
    let spec = alchemist::workloads::TimitSpec {
        train_rows: rows,
        test_rows: 1,
        ..alchemist::workloads::TimitSpec::default()
    };
    let data = spec.generate();
    let map = alchemist::linalg::RffMap::generate(spec.raw_features, d, 0.06, 1);

    let mut table = Table::new(
        "Ablation 4: overhead-model sensitivity (Spark sim s/iter vs scheduler delay)",
        &["delay scale", "scheduler_delay_s", "spark iter sim (s)", "gap vs alchemist"],
    );
    // alchemist reference: one engine run of the same math (2 iters native)
    let alch_per_iter = {
        let comms = alchemist::collectives::LocalComm::group(1, None);
        let mut e = alchemist::compute::NativeEngine::new();
        let z = map.expand(&mut e, &data.x_train)?;
        let res = alchemist::linalg::cg_solve(
            &comms[0],
            &mut e,
            &z,
            &data.y_train,
            rows,
            &CgOptions { lambda: 1e-5, tol: 0.0, max_iters: 3 },
        )?;
        res.iter_secs.iter().sum::<f64>() / res.iter_secs.len() as f64
    };
    for scale in [0.25f64, 1.0, 4.0] {
        let mut cfg = base.clone();
        cfg.overhead.scheduler_delay_s *= scale;
        cfg.overhead.task_launch_s *= scale;
        let mut engine = SparkEngine::new(3, &cfg);
        engine.inject_real_delays = false; // read the sim ledger only
        let z = mllib::rff_expand(
            &mut engine,
            &IndexedRowMatrix::from_local(&data.x_train, 6),
            &map,
        )?;
        let res = mllib::cg_solve(
            &mut engine,
            &z,
            &IndexedRowMatrix::from_local(&data.y_train, 6),
            &CgOptions { lambda: 1e-5, tol: 0.0, max_iters: 3 },
        )?;
        let per_sim: Stats = res.iter_sim_secs.iter().copied().collect();
        table.row(&[
            format!("x{scale}"),
            format!("{:.3}", cfg.overhead.scheduler_delay_s),
            format!("{:.3}", per_sim.mean()),
            format!("{:.1}x", per_sim.mean() / alch_per_iter),
        ]);
    }
    table.print();
    println!("(connects the calibration to Gittens et al. 2016: the gap is overhead-driven)");
    Ok(())
}
