//! Shared level-1 vector kernels (dot / axpy / norm) for the iterative
//! solvers (cg, lanczos, qr) — unrolled into 4-lane `chunks_exact`
//! accumulators so LLVM emits straight-line vector FMA instead of a
//! single serial dependency chain.
//!
//! Determinism note: the 4-lane summation order is *fixed* (lanes
//! combined `(l0+l1) + (l2+l3)`, tail appended last), so every rank of an
//! SPMD solver computing a dot over replicated state gets the bit-same
//! answer — the same contract the engine's chunked reductions follow
//! (`docs/compute.md`).

/// 4-lane unrolled dot product.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() & !3;
    let mut lanes = [0.0f64; 4];
    for (x, y) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4)) {
        lanes[0] += x[0] * y[0];
        lanes[1] += x[1] * y[1];
        lanes[2] += x[2] * y[2];
        lanes[3] += x[3] * y[3];
    }
    let mut tail = 0.0;
    for (x, y) in a[n4..].iter().zip(&b[n4..]) {
        tail += x * y;
    }
    (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]) + tail
}

/// `y += alpha·x`, 4-lane unrolled.
pub fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    let n4 = y.len() & !3;
    for (ys, xs) in y[..n4].chunks_exact_mut(4).zip(x[..n4].chunks_exact(4)) {
        ys[0] += alpha * xs[0];
        ys[1] += alpha * xs[1];
        ys[2] += alpha * xs[2];
        ys[3] += alpha * xs[3];
    }
    for (ys, xs) in y[n4..].iter_mut().zip(&x[n4..]) {
        *ys += alpha * xs;
    }
}

/// Euclidean norm via [`dot`].
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Scale to unit norm (no-op on the zero vector).
pub fn normalize(a: &mut [f64]) {
    let n = norm(a);
    if n > 0.0 {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Kahan (compensated) dot product — the accuracy reference.
    fn kahan_dot(a: &[f64], b: &[f64]) -> f64 {
        let mut sum = 0.0f64;
        let mut c = 0.0f64;
        for (x, y) in a.iter().zip(b) {
            let term = x * y - c;
            let t = sum + term;
            c = (t - sum) - term;
            sum = t;
        }
        sum
    }

    #[test]
    fn dot_exact_on_integers_and_all_tail_lengths() {
        for n in 0..13usize {
            let a: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (2 * i + 1) as f64).collect();
            let want: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert_eq!(dot(&a, &b), want, "n={n}");
        }
    }

    #[test]
    fn dot_accuracy_vs_kahan_on_adversarial_input() {
        // mixed magnitudes (1e-3 .. 1e3 spread per element) with sign
        // flips — heavy cancellation across lanes. The 4-lane sum must
        // stay within a few ULP-sums of the compensated reference:
        // |err| ≤ 1e-12 · Σ|aᵢbᵢ| is ~100x looser than the worst-case
        // n·ε bound for n ≈ 1000, so a regression to sloppier
        // accumulation (or a broken tail) trips it, while any correct
        // reassociation passes.
        let n = 1003usize;
        let a: Vec<f64> = (0..n)
            .map(|i| {
                let mag = 10f64.powi((i % 7) as i32 - 3);
                let sign = if (i / 3) % 2 == 0 { 1.0 } else { -1.0 };
                sign * mag * (1.0 + (i as f64) * 1e-4)
            })
            .collect();
        let b: Vec<f64> = (0..n)
            .map(|i| {
                let mag = 10f64.powi((i % 5) as i32 - 2);
                let sign = if (i / 7) % 2 == 0 { 1.0 } else { -1.0 };
                sign * mag * (2.0 - (i as f64) * 1e-4)
            })
            .collect();
        let want = kahan_dot(&a, &b);
        let got = dot(&a, &b);
        let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        assert!(
            (got - want).abs() <= 1e-12 * scale,
            "dot drifted from Kahan reference: got {got}, want {want} \
             (scale {scale})"
        );
    }

    #[test]
    fn axpy_and_norm_match_naive() {
        let x: Vec<f64> = (0..11).map(|i| i as f64 * 0.5 - 2.0).collect();
        let mut y: Vec<f64> = (0..11).map(|i| 1.0 - i as f64 * 0.25).collect();
        let y0 = y.clone();
        axpy(&mut y, -1.5, &x);
        for i in 0..11 {
            assert_eq!(y[i], y0[i] + (-1.5) * x[i], "i={i}");
        }
        let want: f64 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!((norm(&x) - want).abs() < 1e-12);
    }

    #[test]
    fn normalize_unit_and_zero_safe() {
        let mut v = vec![3.0, 4.0];
        normalize(&mut v);
        assert!((norm(&v) - 1.0).abs() < 1e-15);
        let mut z = vec![0.0; 5];
        normalize(&mut z);
        assert!(z.iter().all(|&x| x == 0.0));
    }
}
