//! Socket plumbing: length-framed streams and a tiny accept-loop helper.
//!
//! The paper's ACI moves all traffic over TCP sockets (Boost.Asio on the
//! C++ side); here it is std-net with explicit buffering — tokio is not in
//! the offline vendor set, and the protocol is strictly request/response
//! per connection, so blocking I/O with one thread per socket reproduces
//! the architecture directly.

pub mod framed;
pub mod server;

pub use framed::{Framed, MAX_FRAME};
pub use server::Server;
