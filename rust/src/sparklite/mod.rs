//! The Spark stand-in (DESIGN.md §2): a partitioned-collection engine
//! with bulk-synchronous stages and an explicit overhead model.
//!
//! The paper's baseline is Spark MLlib running iterative linear algebra;
//! its defining performance property (Gittens et al. 2016, and Tables 2/5
//! here) is that *every* iteration pays per-stage scheduler delay and
//! per-task launch/serde costs, so iterative numerics are overhead-bound
//! and anti-scale. sparklite reproduces that structure:
//!
//! * [`rdd::Rdd`] — immutable partitioned collections;
//! * [`scheduler::SparkEngine`] — runs stages task-by-task, *really
//!   computing* every task, while charging the calibrated overheads
//!   ([`crate::config::OverheadConfig`]) as real injected delay plus
//!   simulated-cluster-time accounting;
//! * [`matrix::IndexedRowMatrix`] — the row-RDD matrix the ACI transfers
//!   (paper §3.1.2);
//! * [`mllib`] — Spark-style CG and truncated SVD baselines whose
//!   per-row, unblocked compute mirrors how MLlib's row matrices work.

pub mod matrix;
pub mod mllib;
pub mod rdd;
pub mod scheduler;

pub use matrix::{IndexedRow, IndexedRowMatrix};
pub use rdd::Rdd;
pub use scheduler::{SparkEngine, StageStats};
