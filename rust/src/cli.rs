//! Dependency-free command-line parsing (clap is not in the offline vendor
//! set). Supports `--flag`, `--key value`, `--key=value`, positionals, and
//! subcommands; every bench/example shares this.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

/// Parsed arguments: options by name (last occurrence wins), boolean flags,
/// and positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list. Tokens starting with `--` are
    /// options; an option consumes the next token as its value unless it
    /// contains `=` or the next token also starts with `--` (then it is a
    /// flag). `--` terminates option parsing.
    pub fn parse_from<I, S>(tokens: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let toks: Vec<String> = tokens.into_iter().map(Into::into).collect();
        let mut args = Args::default();
        let mut i = 0;
        let mut opts_done = false;
        while i < toks.len() {
            let t = &toks[i];
            if opts_done || !t.starts_with("--") {
                args.positional.push(t.clone());
                i += 1;
                continue;
            }
            if t == "--" {
                opts_done = true;
                i += 1;
                continue;
            }
            let body = &t[2..];
            if let Some((k, v)) = body.split_once('=') {
                args.opts.insert(k.to_string(), v.to_string());
                i += 1;
            } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                args.opts.insert(body.to_string(), toks[i + 1].clone());
                i += 2;
            } else {
                args.flags.push(body.to_string());
                i += 1;
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]; also skips a literal
    /// `--bench` token, which `cargo bench` appends to harness-less benches).
    pub fn from_env() -> Self {
        Self::parse_from(
            std::env::args().skip(1).filter(|a| a != "--bench"),
        )
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> crate::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> crate::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .with_context(|| format!("--{name} expects a float, got {v:?}")),
        }
    }

    /// Comma-separated usize list, e.g. `--workers 2,3,4`.
    pub fn get_usize_list(
        &self,
        name: &str,
        default: &[usize],
    ) -> crate::Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|t| {
                    t.trim().parse().with_context(|| {
                        format!("--{name} expects comma-separated integers, got {v:?}")
                    })
                })
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional = subcommand, or error listing the choices.
    pub fn subcommand(&self, choices: &[&str]) -> crate::Result<&str> {
        match self.positional.first() {
            Some(c) if choices.contains(&c.as_str()) => Ok(c),
            Some(c) => bail!("unknown subcommand {c:?}; expected one of {choices:?}"),
            None => bail!("missing subcommand; expected one of {choices:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_styles() {
        // NB: a bare `--flag` followed by a positional is ambiguous (the
        // token would be taken as the flag's value); positionals go first
        // or after `--`.
        let a = Args::parse_from([
            "run", "file.bin", "--workers", "4", "--engine=xla", "--verbose",
        ]);
        assert_eq!(a.positional(), &["run", "file.bin"]);
        assert_eq!(a.get("workers"), Some("4"));
        assert_eq!(a.get("engine"), Some("xla"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn last_option_wins_and_defaults_apply() {
        let a = Args::parse_from(["--n", "1", "--n", "2"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 2);
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert!(a.get_usize("n", 0).is_ok());
        let bad = Args::parse_from(["--n", "x"]);
        assert!(bad.get_usize("n", 0).is_err());
    }

    #[test]
    fn flag_before_flag_and_trailing_flag() {
        let a = Args::parse_from(["--a", "--b", "--c"]);
        assert!(a.flag("a") && a.flag("b") && a.flag("c"));
    }

    #[test]
    fn double_dash_stops_options() {
        let a = Args::parse_from(["--a", "1", "--", "--not-an-opt"]);
        assert_eq!(a.get("a"), Some("1"));
        assert_eq!(a.positional(), &["--not-an-opt"]);
    }

    #[test]
    fn usize_list() {
        let a = Args::parse_from(["--w", "2,3, 4"]);
        assert_eq!(a.get_usize_list("w", &[]).unwrap(), vec![2, 3, 4]);
        assert_eq!(a.get_usize_list("x", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn subcommand_dispatch() {
        let a = Args::parse_from(["serve"]);
        assert_eq!(a.subcommand(&["serve", "info"]).unwrap(), "serve");
        assert!(a.subcommand(&["info"]).is_err());
        assert!(Args::parse_from::<_, String>([])
            .subcommand(&["serve"])
            .is_err());
    }
}
