//! Engine-equivalence suite: the parallel native engine must be
//! **bit-identical** to `threads = 1` for all four `Engine` ops, across
//! thread counts, edge shapes, runtime-dispatched ISA variants, and
//! work-stealing shared-pool clients — the determinism contract that
//! keeps replicated SPMD solver state bitwise-equal across ranks
//! (`docs/compute.md`). Plus a `distributed_matches_serial`-style solver
//! run with the pool enabled, and native-vs-XLA agreement for the
//! `engine = "auto"` dispatcher over synthesized sim artifacts.

use alchemist::collectives::LocalComm;
use alchemist::compute::{
    DispatchEngine, Engine, GemmVariant, NativeEngine, ThreadPool, XlaEngine,
};
use alchemist::config::Config;
use alchemist::distmat::dense::{GEMM_KC, GEMM_MC, GEMM_MR, GEMM_NR};
use alchemist::distmat::{LocalMatrix, RowBlockLayout};
use alchemist::linalg::{cg_solve, truncated_svd, CgOptions, SvdOptions, SvdResult};
use alchemist::simd::{self, Isa};
use alchemist::testkit;
use alchemist::util::prng::Rng;

fn random(rng: &mut Rng, r: usize, c: usize) -> LocalMatrix {
    LocalMatrix::from_fn(r, c, |_, _| rng.normal())
}

/// Edge shapes for the GEMM family: degenerate vectors, tall-skinny,
/// sizes straddling the micro-tile (MR×NR), panel (MC) and k-block (KC)
/// boundaries, and empty-k.
fn gemm_shapes() -> Vec<(usize, usize, usize)> {
    vec![
        (1, 1, 1),
        (1, 17, 5),                    // 1×n row
        (7, 1, 3),                     // n×1 column
        (200, 3, 64),                  // tall-skinny
        (GEMM_MR, GEMM_NR, 4),         // exactly one micro-tile
        (GEMM_MR + 1, GEMM_NR + 1, 5), // one past the micro-tile
        (GEMM_MC - 1, GEMM_NR * 2 + 3, GEMM_KC + 1), // straddles MC and KC
        (GEMM_MC * 2 + 1, 7, 33),      // several parallel panels
        (64, 8, 0),                    // empty-k: gemm is a no-op
    ]
}

#[test]
fn gemm_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(41);
    for (m, n, k) in gemm_shapes() {
        let a = random(&mut rng, m, k);
        let b = random(&mut rng, k, n);
        let at = a.transpose();
        let bt = b.transpose();
        let seed = random(&mut rng, m, n); // nonzero C: gemm accumulates
        for variant in [GemmVariant::NN, GemmVariant::TN, GemmVariant::NT] {
            let (opa, opb) = match variant {
                GemmVariant::NN => (&a, &b),
                GemmVariant::TN => (&at, &b),
                GemmVariant::NT => (&a, &bt),
            };
            let mut want = seed.clone();
            NativeEngine::with_threads(1).gemm(variant, &mut want, opa, opb).unwrap();
            for threads in [2usize, 4] {
                let mut got = seed.clone();
                NativeEngine::with_threads(threads).gemm(variant, &mut got, opa, opb).unwrap();
                assert_eq!(
                    got, want,
                    "{} {m}x{n}x{k} threads={threads}",
                    variant.op_name()
                );
            }
        }
    }
}

#[test]
fn fused_ops_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(42);
    // rows straddle the engine's 256-row chunk grain; cols straddle the
    // micro-tile widths
    for &(rows, d, nrhs) in &[
        (1usize, 5usize, 2usize),
        (255, 9, 1),
        (256, 16, 4),
        (257, 7, 3),
        (600, 37, 5),
        (1, 1, 1),
    ] {
        let a = random(&mut rng, rows, d);
        let v = random(&mut rng, d, nrhs);
        let want = NativeEngine::with_threads(1).gram_matvec(&a, &v, 0.9).unwrap();
        for threads in [2usize, 4] {
            let got = NativeEngine::with_threads(threads).gram_matvec(&a, &v, 0.9).unwrap();
            assert_eq!(got, want, "gram_matvec {rows}x{d}x{nrhs} t={threads}");
        }

        // cg_update: x/r mutated in place
        let x0 = random(&mut rng, rows, nrhs);
        let r0 = random(&mut rng, rows, nrhs);
        let p = random(&mut rng, rows, nrhs);
        let q = random(&mut rng, rows, nrhs);
        let alpha: Vec<f64> = (0..nrhs).map(|_| rng.normal()).collect();
        let (mut xw, mut rw) = (x0.clone(), r0.clone());
        NativeEngine::with_threads(1).cg_update(&mut xw, &mut rw, &p, &q, &alpha).unwrap();
        for threads in [2usize, 4] {
            let (mut xg, mut rg) = (x0.clone(), r0.clone());
            NativeEngine::with_threads(threads)
                .cg_update(&mut xg, &mut rg, &p, &q, &alpha)
                .unwrap();
            assert_eq!(xg, xw, "cg_update x {rows}x{nrhs} t={threads}");
            assert_eq!(rg, rw, "cg_update r {rows}x{nrhs} t={threads}");
        }

        // rff_expand: rows×d input through a d×(2d+1) map
        let omega = random(&mut rng, d, 2 * d + 1);
        let bias: Vec<f64> = (0..2 * d + 1).map(|_| rng.uniform_in(0.0, 6.28)).collect();
        let scale = (2.0f64 / (2 * d + 1) as f64).sqrt();
        let want = NativeEngine::with_threads(1).rff_expand(&a, &omega, &bias, scale).unwrap();
        for threads in [2usize, 4] {
            let got = NativeEngine::with_threads(threads)
                .rff_expand(&a, &omega, &bias, scale)
                .unwrap();
            assert_eq!(got, want, "rff_expand {rows}x{d} t={threads}");
        }
    }
}

#[test]
fn cg_solver_state_bit_identical_across_engine_threads() {
    // the whole iterative solve — not just one op — must be replay-equal
    // across pool sizes: every iterate feeds the next, so a single
    // reassociated reduction anywhere would diverge the trajectories
    let mut rng = Rng::new(43);
    let n = 300usize;
    let x = random(&mut rng, n, 12);
    let y = random(&mut rng, n, 3);
    let opts = CgOptions { lambda: 1e-3, tol: 1e-10, max_iters: 200 };
    let comms = LocalComm::group(1, None);
    let base = cg_solve(&comms[0], &mut NativeEngine::with_threads(1), &x, &y, n, &opts).unwrap();
    for threads in [2usize, 4] {
        let comms = LocalComm::group(1, None);
        let got = cg_solve(&comms[0], &mut NativeEngine::with_threads(threads), &x, &y, n, &opts)
            .unwrap();
        assert_eq!(got.w, base.w, "threads={threads}");
        assert_eq!(got.iters, base.iters, "threads={threads}");
        assert_eq!(got.residuals, base.residuals, "threads={threads}");
    }
}

/// Runtime ISA dispatch must be invisible in the results: every SIMD
/// variant runnable on this host produces *bit-identical* output to the
/// portable fallback (the variants use unfused mul+add in the same
/// accumulation order — no FMA contraction), on the same micro-tile /
/// panel / k-block edge shapes as the thread-count suite. On hosts
/// without AVX2, `available()` is just `[Fallback]` and the inner loop
/// is vacuous — the test still pins the fallback path against itself.
#[test]
fn isa_variants_bit_identical_to_fallback_on_edge_shapes() {
    let mut rng = Rng::new(45);
    for (m, n, k) in gemm_shapes() {
        let a = random(&mut rng, m, k);
        let b = random(&mut rng, k, n);
        let seed = random(&mut rng, m, n);
        let mut want = seed.clone();
        simd::with_isa(Isa::Fallback, || {
            NativeEngine::with_threads(1).gemm(GemmVariant::NN, &mut want, &a, &b).unwrap()
        });
        for isa in simd::available() {
            let mut got = seed.clone();
            simd::with_isa(isa, || {
                NativeEngine::with_threads(2)
                    .gemm(GemmVariant::NN, &mut got, &a, &b)
                    .unwrap()
            });
            assert_eq!(got, want, "{} gemm {m}x{n}x{k}", isa.name());
        }
    }

    // the fused ops ride the same micro-kernel and blas1 variants
    let a = random(&mut rng, 300, 17);
    let v = random(&mut rng, 17, 3);
    let want = simd::with_isa(Isa::Fallback, || {
        NativeEngine::with_threads(1).gram_matvec(&a, &v, 0.7).unwrap()
    });
    for isa in simd::available() {
        let got = simd::with_isa(isa, || {
            NativeEngine::with_threads(2).gram_matvec(&a, &v, 0.7).unwrap()
        });
        assert_eq!(got, want, "{} gram_matvec", isa.name());
    }
}

/// The two backends the `engine = "auto"` dispatcher chooses between
/// only agree to rounding error (tiling pads and reorders reductions),
/// so pin that tolerance here over synthesized sim artifacts — plus the
/// routing invariant the cost table guarantees: composed GEMM always
/// lands on the native packed kernels (bitwise-equal, not just close).
#[test]
fn xla_and_auto_engines_agree_with_native_on_sim_artifacts() {
    let dir = std::env::temp_dir().join(format!("alch_it_dispatch_{}", std::process::id()));
    testkit::write_sim_artifacts(&dir, 64, 128, 64, 8).unwrap();
    let mut cfg = Config::default();
    cfg.apply("artifacts_dir", dir.to_str().unwrap()).unwrap();
    cfg.apply("tile", "64").unwrap();
    cfg.apply("panel_rows", "128").unwrap();

    let mut rng = Rng::new(46);
    let a = random(&mut rng, 100, 48); // off-tile: exercises padding
    let b = random(&mut rng, 48, 60);
    let mut want = LocalMatrix::zeros(100, 60);
    NativeEngine::with_threads(1).gemm(GemmVariant::NN, &mut want, &a, &b).unwrap();

    let mut xla = XlaEngine::new(&cfg, "xla").unwrap();
    let mut got = LocalMatrix::zeros(100, 60);
    xla.gemm(GemmVariant::NN, &mut got, &a, &b).unwrap();
    for (g, w) in got.data().iter().zip(want.data()) {
        assert!((g - w).abs() <= 1e-8 * w.abs().max(1.0), "xla gemm: {g} vs {w}");
    }

    let mut auto = DispatchEngine::new(&cfg, NativeEngine::with_threads(2));
    assert!(auto.has_xla(), "sim artifacts should load");
    let mut got = LocalMatrix::zeros(100, 60);
    auto.gemm(GemmVariant::NN, &mut got, &a, &b).unwrap();
    assert_eq!(got, want, "auto must route composed GEMM to the native kernels");

    // fused op: whichever backend the table picks must stay within the
    // cross-backend tolerance of the native oracle
    let v = random(&mut rng, 48, 2);
    let want = NativeEngine::with_threads(1).gram_matvec(&a, &v, 0.4).unwrap();
    let got = auto.gram_matvec(&a, &v, 0.4).unwrap();
    for (g, w) in got.data().iter().zip(want.data()) {
        assert!((g - w).abs() <= 1e-7 * w.abs().max(1.0), "auto gram: {g} vs {w}");
    }

    std::fs::remove_dir_all(&dir).ok();
}

/// Re-pin the determinism contract with work stealing active: engines on
/// client queues of one shared root pool, running concurrently so idle
/// workers actually steal across home queues, must stay bit-identical to
/// the single-threaded private-pool result at every thread budget.
#[test]
fn determinism_across_thread_counts_with_shared_pool_stealing() {
    let mut rng = Rng::new(47);
    let m = GEMM_MC * 3 + 5; // several parallel panels per call
    let a = random(&mut rng, m, 40);
    let b = random(&mut rng, 40, 24);
    let seed = random(&mut rng, m, 24);
    let v = random(&mut rng, 40, 3);
    let mut want = seed.clone();
    NativeEngine::with_threads(1).gemm(GemmVariant::NN, &mut want, &a, &b).unwrap();
    let want_gram = NativeEngine::with_threads(1).gram_matvec(&a, &v, 0.6).unwrap();

    let root = ThreadPool::new(4);
    for t in [1usize, 2, 4] {
        let mut handles = Vec::new();
        for _ in 0..2 {
            let mut engine = NativeEngine::from_pool(root.client(t));
            let (a, b, v, seed) = (a.clone(), b.clone(), v.clone(), seed.clone());
            handles.push(std::thread::spawn(move || {
                let mut got = seed;
                engine.gemm(GemmVariant::NN, &mut got, &a, &b).unwrap();
                let gram = engine.gram_matvec(&a, &v, 0.6).unwrap();
                (got, gram)
            }));
        }
        for h in handles {
            let (got, gram) = h.join().unwrap();
            assert_eq!(got, want, "gemm under stealing, threads={t}");
            assert_eq!(gram, want_gram, "gram under stealing, threads={t}");
        }
    }
}

/// `distributed_matches_serial` with the pool enabled: pooled engines on
/// every rank must keep (a) the replicated SPMD state bitwise-equal
/// across ranks, (b) the whole distributed result bit-identical to the
/// same distributed run at `threads = 1`, and (c) the spectrum close to
/// the serial single-rank solve.
#[test]
fn distributed_svd_matches_serial_with_pool_enabled() {
    let mut rng = Rng::new(44);
    let n = 320usize;
    let k_dim = 24usize;
    let a = random(&mut rng, n, k_dim);
    let opts = SvdOptions { rank: 3, steps: 0, seed: 2 };

    let serial = {
        let comms = LocalComm::group(1, None);
        truncated_svd(&comms[0], &mut NativeEngine::with_threads(1), &a, &opts).unwrap()
    };

    let run_distributed = |workers: usize, threads: usize| -> Vec<SvdResult> {
        let layout = RowBlockLayout::even(n, k_dim, workers);
        let comms = LocalComm::group(workers, None);
        let mut handles = Vec::new();
        for comm in comms {
            let (ra, rb) = layout.ranges[comm.rank()];
            let local = a.slice_rows(ra, rb);
            let opts = opts.clone();
            handles.push(std::thread::spawn(move || {
                truncated_svd(
                    &comm,
                    &mut NativeEngine::with_threads(threads),
                    &local,
                    &opts,
                )
                .unwrap()
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    };

    for workers in [2usize, 3] {
        let base = run_distributed(workers, 1);
        let pooled = run_distributed(workers, 2);
        for (rank, res) in pooled.iter().enumerate() {
            // (a) replicated state identical across ranks
            assert_eq!(res.v, pooled[0].v, "workers={workers} rank={rank}");
            assert_eq!(res.sigma, pooled[0].sigma, "workers={workers} rank={rank}");
            // (b) pool-invariance of the full distributed run
            assert_eq!(res.v, base[rank].v, "workers={workers} rank={rank}");
            assert_eq!(res.sigma, base[rank].sigma, "workers={workers} rank={rank}");
            assert_eq!(
                res.u_local.data(),
                base[rank].u_local.data(),
                "workers={workers} rank={rank}"
            );
            // (c) correct spectrum vs the serial solve
            for (g, w) in res.sigma.iter().zip(&serial.sigma) {
                assert!((g - w).abs() < 1e-6, "workers={workers}: {g} vs {w}");
            }
        }
    }
}
