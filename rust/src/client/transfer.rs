//! Executor-side row transfer: push partitions to workers / pull row
//! ranges back, over per-executor TCP sockets (paper §3.2 "Direct
//! Transfer").
//!
//! Each executor thread owns one socket per worker it talks to. Pushes
//! batch rows `rows_per_frame` at a time into borrowed-payload
//! `PushRows` frames (contiguous runs only — a run breaks whenever the
//! destination worker or row continuity changes), reusing one frame
//! buffer per executor so steady state allocates nothing; the stream is
//! acknowledged once per worker by `PushDone`.
//!
//! Pulls use the v3 streaming protocol: each executor splits its row
//! share into ranged stripes (`pull_stripe_rows` rows each), keeps up to
//! `pull_window` stripes outstanding per worker link, and primes every
//! link before draining any — the per-frame request/reply round-trip of
//! the old protocol is gone, and the link currently being drained never
//! idles (its window is topped back up as stripes complete). Within one
//! executor the links drain in worker order, so a worker past the first
//! streams its initial `pull_window` stripes and then waits on TCP
//! backpressure until drained; cross-worker overlap beyond that window
//! comes from running several executor threads, each covering a
//! different contiguous row share (and therefore mostly different
//! workers).

use std::collections::VecDeque;
use std::time::Instant;

use crate::config::TransferConfig;
use crate::net::{Framed, MAX_FRAME};
use crate::protocol::{
    copy_le_f64s, max_rows_per_frame_for, DataMsg, DataMsgRef, DataMsgView,
};
use crate::sparklite::IndexedRow;

use super::almatrix::AlMatrix;

/// Measured cost of one distributed transfer.
#[derive(Debug, Clone, Default)]
pub struct TransferStats {
    pub bytes: usize,
    pub secs: f64,
    pub frames: usize,
    pub executors: usize,
}

impl TransferStats {
    pub fn throughput_gbps(&self) -> f64 {
        if self.secs > 0.0 {
            self.bytes as f64 / self.secs / 1e9
        } else {
            0.0
        }
    }

    /// Fold another transfer's stats into this one: volumes add, wallclock
    /// takes the max (executors run concurrently), and so does the
    /// executor count — merging a per-thread share (executors = 0) into a
    /// whole-transfer record must not erase the transfer's parallelism.
    pub fn merge(&mut self, other: &TransferStats) {
        self.bytes += other.bytes;
        self.frames += other.frames;
        self.secs = self.secs.max(other.secs);
        self.executors = self.executors.max(other.executors);
    }
}

#[cfg(test)]
mod tests {
    use super::TransferStats;

    #[test]
    fn merge_keeps_executors_and_concurrent_semantics() {
        let mut total = TransferStats { executors: 4, ..Default::default() };
        let a = TransferStats { bytes: 100, secs: 0.5, frames: 2, executors: 0 };
        let b = TransferStats { bytes: 300, secs: 0.2, frames: 1, executors: 0 };
        total.merge(&a);
        total.merge(&b);
        assert_eq!(total.bytes, 400);
        assert_eq!(total.frames, 3);
        assert_eq!(total.secs, 0.5); // slowest concurrent executor
        assert_eq!(total.executors, 4); // not clobbered by per-thread shares

        // merging two whole-transfer records (e.g. push + pull legs)
        let mut push = TransferStats { bytes: 8, secs: 1.0, frames: 1, executors: 2 };
        let pull = TransferStats { bytes: 8, secs: 2.0, frames: 1, executors: 3 };
        push.merge(&pull);
        assert_eq!(push.executors, 3);
    }
}

/// One executor's sockets to the workers it talks to (lazily opened).
struct ExecutorLinks<'a> {
    worker_addrs: &'a [String],
    cfg: &'a TransferConfig,
    links: Vec<Option<Framed<std::net::TcpStream, std::net::TcpStream>>>,
    session_id: u64,
    executor_id: u32,
}

impl<'a> ExecutorLinks<'a> {
    fn new(
        worker_addrs: &'a [String],
        cfg: &'a TransferConfig,
        session_id: u64,
        executor_id: u32,
    ) -> Self {
        ExecutorLinks {
            worker_addrs,
            cfg,
            links: (0..worker_addrs.len()).map(|_| None).collect(),
            session_id,
            executor_id,
        }
    }

    fn link(
        &mut self,
        rank: usize,
    ) -> crate::Result<&mut Framed<std::net::TcpStream, std::net::TcpStream>> {
        if self.links[rank].is_none() {
            let mut f =
                Framed::connect(&self.worker_addrs[rank], self.cfg.buf_bytes)?;
            f.send_data_flush(&DataMsg::DataHandshake {
                session_id: self.session_id,
                executor_id: self.executor_id,
                // pull replies should stream at this session's negotiated
                // frame granularity (the worker clamps to its own limit)
                rows_per_frame: self.cfg.rows_per_frame as u32,
            })?;
            match f.recv_data()? {
                DataMsg::DataHandshakeAck { worker_rank } => {
                    anyhow::ensure!(
                        worker_rank as usize == rank,
                        "connected to worker {worker_rank}, expected {rank}"
                    );
                }
                DataMsg::DataError { message } => {
                    anyhow::bail!("data handshake rejected: {message}")
                }
                other => anyhow::bail!("bad data handshake reply: {other:?}"),
            }
            self.links[rank] = Some(f);
        }
        Ok(self.links[rank].as_mut().unwrap())
    }
}

/// Push one executor's share of rows. `rows` need not be sorted; batching
/// exploits contiguity when present. The frame accumulator is reused
/// across frames (cleared, never reallocated), and `send_data_ref`
/// copies it straight into the socket buffer — zero per-frame heap
/// allocation in steady state.
fn push_rows_one_executor(
    matrix: &AlMatrix,
    rows: &[&IndexedRow],
    links: &mut ExecutorLinks,
    rows_per_frame: usize,
) -> crate::Result<TransferStats> {
    let t0 = Instant::now();
    let ncols = matrix.cols;
    // same frame cap as the worker's pull streams (one shared helper):
    // clamp rows-per-frame so header + payload fits under MAX_FRAME for
    // any matrix width — and reject up front a matrix whose single row
    // cannot fit, rather than failing mid-stream after frames already
    // landed on the worker
    let cap_rows = max_rows_per_frame_for(ncols, MAX_FRAME as usize).ok_or_else(|| {
        anyhow::anyhow!(
            "matrix {}: one row of {ncols} cols exceeds the {MAX_FRAME} byte frame cap",
            matrix.id
        )
    })?;
    let rows_per_frame = rows_per_frame.min(cap_rows);
    let mut stats = TransferStats::default();
    let mut touched = vec![false; matrix.row_ranges.len()];

    // current run being accumulated (one reusable frame buffer)
    let mut run_start: u64 = 0;
    let mut run_owner: usize = usize::MAX;
    let mut run_data: Vec<f64> = Vec::with_capacity(rows_per_frame * ncols);
    let mut run_rows: u32 = 0;

    let flush = |owner: usize,
                     start: u64,
                     nrows: u32,
                     data: &mut Vec<f64>,
                     stats: &mut TransferStats,
                     links: &mut ExecutorLinks|
     -> crate::Result<()> {
        if nrows == 0 {
            return Ok(());
        }
        links.link(owner)?.send_data_ref(&DataMsgRef::PushRows {
            matrix_id: matrix.id,
            start_row: start,
            nrows,
            ncols: ncols as u32,
            data: data.as_slice(),
        })?;
        stats.bytes += nrows as usize * ncols * 8;
        stats.frames += 1;
        data.clear();
        Ok(())
    };

    for row in rows {
        anyhow::ensure!(
            row.vector.len() == ncols,
            "row {} has {} cols, matrix has {ncols}",
            row.index,
            row.vector.len()
        );
        let owner = matrix.owner_of(row.index as usize);
        touched[owner] = true;
        let contiguous = run_rows > 0
            && owner == run_owner
            && row.index == run_start + run_rows as u64
            && (run_rows as usize) < rows_per_frame;
        if !contiguous {
            flush(run_owner, run_start, run_rows, &mut run_data, &mut stats, links)?;
            run_start = row.index;
            run_owner = owner;
            run_rows = 0;
        }
        run_data.extend_from_slice(&row.vector);
        run_rows += 1;
    }
    flush(run_owner, run_start, run_rows, &mut run_data, &mut stats, links)?;

    // end-of-stream ack per touched worker
    for (rank, used) in touched.iter().enumerate() {
        if *used {
            let link = links.link(rank)?;
            link.send_data_flush(&DataMsg::PushDone { matrix_id: matrix.id })?;
            match link.recv_data()? {
                DataMsg::PushDoneAck { .. } => {}
                DataMsg::DataError { message } => anyhow::bail!("push failed: {message}"),
                other => anyhow::bail!("bad push ack: {other:?}"),
            }
        }
    }
    stats.secs = t0.elapsed().as_secs_f64();
    Ok(stats)
}

/// Push all partitions with `executors` concurrent sender threads
/// (partition list split evenly). Returns merged stats (secs = slowest
/// executor, the paper's transfer-time definition).
pub fn push_matrix(
    matrix: &AlMatrix,
    partitions: &[Vec<IndexedRow>],
    worker_addrs: &[String],
    cfg: &TransferConfig,
    session_id: u64,
    executors: usize,
) -> crate::Result<TransferStats> {
    let executors = executors.max(1);
    let assignment = crate::util::even_ranges(partitions.len(), executors);
    let t0 = Instant::now();
    let mut merged = TransferStats { executors, ..Default::default() };
    std::thread::scope(|scope| -> crate::Result<()> {
        let mut handles = Vec::new();
        for (eid, &(a, b)) in assignment.iter().enumerate() {
            let parts = &partitions[a..b];
            handles.push(scope.spawn(move || -> crate::Result<TransferStats> {
                if parts.is_empty() {
                    return Ok(TransferStats::default());
                }
                let mut links =
                    ExecutorLinks::new(worker_addrs, cfg, session_id, eid as u32);
                let rows: Vec<&IndexedRow> = parts.iter().flatten().collect();
                let stats = push_rows_one_executor(
                    matrix,
                    &rows,
                    &mut links,
                    cfg.rows_per_frame.max(1),
                )?;
                // polite close
                for link in links.links.iter_mut().flatten() {
                    let _ = link.send_data_flush(&DataMsg::DataBye);
                }
                Ok(stats)
            }));
        }
        for h in handles {
            let stats = h.join().map_err(|_| anyhow::anyhow!("executor thread panicked"))??;
            merged.merge(&stats);
        }
        Ok(())
    })?;
    merged.secs = t0.elapsed().as_secs_f64();
    Ok(merged)
}

/// One outstanding ranged pull request.
#[derive(Debug, Clone, Copy)]
struct PullReq {
    start: usize,
    nrows: usize,
}

/// Adaptive pull-side backpressure: the effective per-link window is the
/// byte budget (`transfer.pull_window_bytes`) divided by the stripe
/// size, clamped to `[1, pull_window]`. In-flight stripes are bytes the
/// worker has serialized (or will imminently) that the client has not
/// drained, so a fixed stripe *count* lets wide matrices queue hundreds
/// of megabytes per link; the byte budget keeps the in-flight unacked
/// volume flat while narrow matrices still pipeline up to the hard cap.
fn adaptive_pull_window(stripe_bytes: usize, cfg: &TransferConfig) -> usize {
    let cap = cfg.pull_window.max(1);
    if cfg.pull_window_bytes == 0 {
        return cap;
    }
    (cfg.pull_window_bytes / stripe_bytes.max(1)).clamp(1, cap)
}

/// Pull one executor's share `[lo, hi)` via the v3 streaming protocol.
/// `col_range = (start_col, width)` selects a column window (protocol
/// v7); width 0 means every column, keeping the v6 wire shape.
fn pull_rows_one_executor(
    matrix: &AlMatrix,
    links: &mut ExecutorLinks,
    cfg: &TransferConfig,
    lo: usize,
    hi: usize,
    col_range: (usize, usize),
) -> crate::Result<(Vec<IndexedRow>, TransferStats)> {
    let te = Instant::now();
    let mut rows = Vec::with_capacity(hi.saturating_sub(lo));
    let mut stats = TransferStats::default();
    if lo >= hi {
        return Ok((rows, stats));
    }
    let nworkers = matrix.row_ranges.len();
    let (col0, sel_cols) = col_range;
    // the row width this pull actually moves (replies carry ncols = this)
    let ncols = if sel_cols == 0 { matrix.cols } else { sel_cols };
    anyhow::ensure!(ncols > 0, "matrix {} has zero columns", matrix.id);
    anyhow::ensure!(
        col0 + ncols <= matrix.cols,
        "column range [{col0}, {}) out of bounds for {} cols",
        col0 + ncols,
        matrix.cols
    );

    // carve the share into per-worker ranged stripes
    let stripe_rows = cfg
        .pull_stripe_rows
        .max(cfg.rows_per_frame)
        .clamp(1, u32::MAX as usize);
    let mut stripes: Vec<VecDeque<PullReq>> = vec![VecDeque::new(); nworkers];
    let mut i = lo;
    while i < hi {
        let owner = matrix.owner_of(i);
        let (_, owner_end) = matrix.row_ranges[owner];
        let seg_end = hi.min(owner_end);
        let mut s = i;
        while s < seg_end {
            let e = (s + stripe_rows).min(seg_end);
            stripes[owner].push_back(PullReq { start: s, nrows: e - s });
            s = e;
        }
        i = seg_end;
    }

    let window = adaptive_pull_window(stripe_rows.saturating_mul(ncols * 8), cfg);
    let send_req = |link: &mut Framed<std::net::TcpStream, std::net::TcpStream>,
                    req: PullReq|
     -> crate::Result<()> {
        link.send_data(&DataMsg::PullRows {
            matrix_id: matrix.id,
            start_row: req.start as u64,
            nrows: req.nrows as u32,
            start_col: col0 as u64,
            sel_cols: sel_cols as u32,
        })
    };

    // prime every involved link with up to `window` outstanding ranged
    // requests: all workers start streaming before we drain anything
    let mut inflight: Vec<VecDeque<PullReq>> = vec![VecDeque::new(); nworkers];
    for w in 0..nworkers {
        if stripes[w].is_empty() {
            continue;
        }
        let link = links.link(w)?;
        for _ in 0..window {
            if let Some(req) = stripes[w].pop_front() {
                send_req(link, req)?;
                inflight[w].push_back(req);
            }
        }
        link.flush()?;
    }

    // drain each link's reply streams in request order, topping the
    // window back up as stripes complete so the socket never idles
    for w in 0..nworkers {
        while let Some(req) = inflight[w].pop_front() {
            if let Some(next) = stripes[w].pop_front() {
                let link = links.link(w)?;
                send_req(link, next)?;
                link.flush()?;
                inflight[w].push_back(next);
            }
            let link = links.link(w)?;
            let mut got = 0usize;
            loop {
                match link.recv_data_view()? {
                    DataMsgView::RowsData { matrix_id, start_row, nrows, ncols: nc, payload } => {
                        anyhow::ensure!(
                            matrix_id == matrix.id && nc as usize == ncols,
                            "pull reply mismatch"
                        );
                        let nrows = nrows as usize;
                        anyhow::ensure!(
                            start_row as usize == req.start + got
                                && got + nrows <= req.nrows,
                            "pull stream out of order"
                        );
                        stats.bytes += payload.len();
                        stats.frames += 1;
                        // single copy: frame receive buffer -> row vectors
                        for (k, chunk) in payload.chunks_exact(ncols * 8).enumerate() {
                            let mut v = vec![0f64; ncols];
                            copy_le_f64s(chunk, &mut v);
                            rows.push(IndexedRow {
                                index: (req.start + got + k) as u64,
                                vector: v,
                            });
                        }
                        got += nrows;
                    }
                    DataMsgView::Other(DataMsg::PullDone { matrix_id }) => {
                        anyhow::ensure!(
                            matrix_id == matrix.id && got == req.nrows,
                            "pull stream ended short: {got} of {} rows",
                            req.nrows
                        );
                        break;
                    }
                    DataMsgView::Other(DataMsg::DataError { message }) => {
                        anyhow::bail!("pull failed: {message}")
                    }
                    other => anyhow::bail!("bad pull reply: {other:?}"),
                }
            }
        }
    }
    stats.secs = te.elapsed().as_secs_f64();
    Ok((rows, stats))
}

/// Pull the whole matrix back with `executors` concurrent threads; each
/// covers an even share of the global rows via streaming ranged requests
/// (see the module docs). Returns the rows (unordered) plus stats.
pub fn pull_matrix(
    matrix: &AlMatrix,
    worker_addrs: &[String],
    cfg: &TransferConfig,
    session_id: u64,
    executors: usize,
) -> crate::Result<(Vec<IndexedRow>, TransferStats)> {
    pull_matrix_cols(matrix, worker_addrs, cfg, session_id, executors, 0, 0)
}

/// [`pull_matrix`] restricted to the column window
/// `[start_col, start_col + sel_cols)` (protocol v7; `sel_cols = 0`
/// pulls every column). Each returned row vector has `sel_cols`
/// elements — a client reading a few columns of a wide matrix moves
/// only those bytes.
pub fn pull_matrix_cols(
    matrix: &AlMatrix,
    worker_addrs: &[String],
    cfg: &TransferConfig,
    session_id: u64,
    executors: usize,
    start_col: usize,
    sel_cols: usize,
) -> crate::Result<(Vec<IndexedRow>, TransferStats)> {
    let executors = executors.max(1);
    let shares = crate::util::even_ranges(matrix.rows, executors);
    let t0 = Instant::now();
    let mut all_rows: Vec<IndexedRow> = Vec::with_capacity(matrix.rows);
    let mut merged = TransferStats { executors, ..Default::default() };
    std::thread::scope(|scope| -> crate::Result<()> {
        let mut handles = Vec::new();
        for (eid, &(lo, hi)) in shares.iter().enumerate() {
            handles.push(scope.spawn(
                move || -> crate::Result<(Vec<IndexedRow>, TransferStats)> {
                    let mut links =
                        ExecutorLinks::new(worker_addrs, cfg, session_id, eid as u32);
                    let out = pull_rows_one_executor(
                        matrix,
                        &mut links,
                        cfg,
                        lo,
                        hi,
                        (start_col, sel_cols),
                    )?;
                    for link in links.links.iter_mut().flatten() {
                        let _ = link.send_data_flush(&DataMsg::DataBye);
                    }
                    Ok(out)
                },
            ));
        }
        for h in handles {
            let (rows, stats) =
                h.join().map_err(|_| anyhow::anyhow!("executor thread panicked"))??;
            all_rows.extend(rows);
            merged.merge(&stats);
        }
        Ok(())
    })?;
    merged.secs = t0.elapsed().as_secs_f64();
    Ok((all_rows, merged))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_window_scales_with_stripe_bytes() {
        let cfg = crate::config::Config::default().transfer;
        // default: 1024-row stripes × 1024 cols × 8 B = 8 MiB per
        // stripe, 32 MiB budget → the full default window of 4
        assert_eq!(adaptive_pull_window(8 << 20, &cfg), cfg.pull_window);
        // wide stripes: only as many as fit in the byte budget...
        assert_eq!(adaptive_pull_window(16 << 20, &cfg), 2);
        // ...flooring at one outstanding stripe, never zero
        assert_eq!(adaptive_pull_window(256 << 20, &cfg), 1);
        // narrow stripes pipeline deeply but stay under the hard cap
        assert_eq!(adaptive_pull_window(1, &cfg), cfg.pull_window);
        // budget 0 disables the byte-based scaling entirely
        let mut free = cfg.clone();
        free.pull_window_bytes = 0;
        assert_eq!(adaptive_pull_window(1 << 30, &free), free.pull_window);
    }
}
