//! Truncated SVD via Lanczos on the Gram operator — the ARPACK-style
//! routine behind paper §4.2 (footnote 3: both MLlib and the MPI
//! implementation compute eigenvalues of the Gram matrix).
//!
//! For a row-distributed A (n×K), run Lanczos with full
//! reorthogonalization on `G = AᵀA` (K×K, applied matrix-free through the
//! engine's fused `gram_matvec` + one allreduce), solve the projected
//! tridiagonal problem with [`super::tridiag::tql2`], extract the top-k
//! Ritz pairs, and recover the left singular vectors `U = A·V·Σ⁻¹`
//! locally (U inherits A's row distribution).

use super::blas1::{axpy, dot, norm, normalize};
use crate::collectives::{allreduce_sum, Communicator};
use crate::compute::Engine;
use crate::distmat::LocalMatrix;
use crate::tasks::TaskScope;
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct SvdOptions {
    /// Number of singular triplets to return.
    pub rank: usize,
    /// Lanczos steps (0 = auto: `min(K, 2·rank + 24)`).
    pub steps: usize,
    /// Seed for the (replicated) start vector.
    pub seed: u64,
}

impl Default for SvdOptions {
    fn default() -> Self {
        SvdOptions { rank: 20, steps: 0, seed: 0x53D5 }
    }
}

#[derive(Debug)]
pub struct SvdResult {
    /// Top singular values, descending (length `rank`).
    pub sigma: Vec<f64>,
    /// Right singular vectors, K×rank (replicated).
    pub v: LocalMatrix,
    /// This rank's rows of the left singular vectors, local_rows×rank.
    pub u_local: LocalMatrix,
    /// Lanczos steps actually taken.
    pub steps: usize,
}

const TAG: u64 = 0x5644_0000;

/// Row-panel access to this rank's share of A — the out-of-core seam.
/// In-memory runs hand the whole `LocalMatrix` as one borrowed panel;
/// streaming runs (`coordinator::store::Block`) materialize bounded row
/// spans on demand, so the SVD never needs the full block on the heap.
pub trait RowPanels {
    /// Rows this rank holds.
    fn rows(&self) -> usize;
    /// Column count (identical on every rank).
    fn cols(&self) -> usize;
    /// Materialize local rows `[start, start + n)` as an n×cols matrix.
    /// Borrowed when the source already holds them contiguously in
    /// memory, owned when they must be gathered (mapped / spilled
    /// blocks, partial slices).
    fn panel(&self, start: usize, n: usize)
        -> crate::Result<std::borrow::Cow<'_, LocalMatrix>>;
}

impl RowPanels for LocalMatrix {
    fn rows(&self) -> usize {
        LocalMatrix::rows(self)
    }

    fn cols(&self) -> usize {
        LocalMatrix::cols(self)
    }

    fn panel(
        &self,
        start: usize,
        n: usize,
    ) -> crate::Result<std::borrow::Cow<'_, LocalMatrix>> {
        if start == 0 && n == LocalMatrix::rows(self) {
            // whole-block panel: zero-copy, so the single-panel run is
            // exactly the classic in-memory algorithm
            Ok(std::borrow::Cow::Borrowed(self))
        } else {
            Ok(std::borrow::Cow::Owned(self.slice_rows(start, start + n)))
        }
    }
}

/// SPMD truncated SVD of the row-distributed matrix whose local block is
/// `a_local` (all ranks must pass the same `opts`). Runs under a detached
/// [`TaskScope`] — never cancelled, progress unobserved.
pub fn truncated_svd(
    comm: &dyn Communicator,
    engine: &mut dyn Engine,
    a_local: &LocalMatrix,
    opts: &SvdOptions,
) -> crate::Result<SvdResult> {
    truncated_svd_scoped(comm, engine, a_local, opts, &TaskScope::detached())
}

/// [`truncated_svd`] under an explicit [`TaskScope`]: each Lanczos step
/// reports `(step, β_j)` (the off-diagonal norm stands in for a residual)
/// and cancellation is decided *collectively* at the step boundary — the
/// locally-observed token is allreduced so every rank bails together (see
/// `linalg::cg` for why a unilateral bail would deadlock the group).
pub fn truncated_svd_scoped(
    comm: &dyn Communicator,
    engine: &mut dyn Engine,
    a_local: &LocalMatrix,
    opts: &SvdOptions,
    scope: &TaskScope,
) -> crate::Result<SvdResult> {
    // one whole-block panel — borrowed, so this is the classic in-memory
    // algorithm verbatim (identical engine calls, identical bits)
    truncated_svd_panels(comm, engine, a_local, 0, opts, scope)
}

/// Streaming truncated SVD over [`RowPanels`] (the out-of-core path):
/// `panel_rows` bounds how many of this rank's rows are materialized at
/// once (0 = the whole block as one panel). Each Lanczos step applies
/// the Gram operator panel by panel — `w = Σᵢ AᵢᵀAᵢ·v` — and the final
/// `U = A·V·Σ⁻¹` is recovered panel by panel too, so peak residency is
/// one panel plus the K×K-scale replicated state. With one panel the
/// arithmetic (and therefore every output bit) matches
/// [`truncated_svd_scoped`]; with several, only the summation order of
/// the Gram products differs.
pub fn truncated_svd_panels(
    comm: &dyn Communicator,
    engine: &mut dyn Engine,
    a: &dyn RowPanels,
    panel_rows: usize,
    opts: &SvdOptions,
    scope: &TaskScope,
) -> crate::Result<SvdResult> {
    let k_dim = a.cols();
    let local_rows = a.rows();
    anyhow::ensure!(opts.rank >= 1, "rank must be >= 1");
    anyhow::ensure!(
        opts.rank <= k_dim,
        "rank {} exceeds column count {k_dim}",
        opts.rank
    );
    let m = if opts.steps == 0 {
        (2 * opts.rank + 24).min(k_dim)
    } else {
        opts.steps.min(k_dim)
    };

    // Replicated deterministic start vector: all ranks generate the same.
    let mut rng = Rng::new(opts.seed);
    let mut v0: Vec<f64> = rng.normals(k_dim);
    normalize(&mut v0);

    // Lanczos with full reorthogonalization (K is small — ≤ a few
    // thousand — so keeping the basis replicated is what the paper's
    // implementation does too).
    let mut basis: Vec<Vec<f64>> = vec![v0];
    let mut alphas: Vec<f64> = Vec::new();
    let mut betas: Vec<f64> = Vec::new();
    // this rank's panel grid; panel_rows = 0 means one whole-block panel
    let p = if panel_rows == 0 { local_rows.max(1) } else { panel_rows.max(1) };
    let starts: Vec<usize> = (0..local_rows).step_by(p).collect();
    // A is static across all Lanczos steps: one operand key per panel, so
    // device-backed engines keep each panel resident (§Perf)
    let keys: Vec<_> = starts
        .iter()
        .map(|_| crate::compute::fresh_operand_key())
        .collect();

    for j in 0..m {
        // collective cancellation check at the step boundary (steps are
        // synchronized by the Gram allreduce below, so all ranks reach
        // this together and agree); free for detached scopes
        scope.collective_check_cancelled(
            comm,
            TAG + (1 + 2 * (j as u64 % 64)) * crate::collectives::TAG_WINDOW,
        )?;

        // w = G·vj (matrix-free, reg = 0), accumulated panel by panel;
        // one clone to column-matrix form — `basis[j]` itself stays
        // borrowed for the α/β updates. The first panel's product is
        // MOVED into the accumulator, never added to a zero vector
        // (0.0 + -0.0 flips signs, which would cost the single-panel
        // path its bit-identity with the classic algorithm).
        let vj_mat = LocalMatrix::from_data(k_dim, 1, basis[j].clone());
        let mut acc: Option<LocalMatrix> = None;
        for (i, &s) in starts.iter().enumerate() {
            let n = p.min(local_rows - s);
            let panel = a.panel(s, n)?;
            let wp = engine.gram_matvec_keyed(keys[i], panel.as_ref(), &vj_mat, 0.0)?;
            match &mut acc {
                None => acc = Some(wp),
                Some(accm) => axpy(accm.data_mut(), 1.0, wp.data()),
            }
        }
        // a rank holding zero rows contributes zeros to the allreduce
        let mut w = acc.unwrap_or_else(|| LocalMatrix::zeros(k_dim, 1));
        allreduce_sum(
            comm,
            TAG + (2 * (j as u64 % 64)) * crate::collectives::TAG_WINDOW,
            w.data_mut(),
        )?;
        let mut w = w.into_data();

        let alpha = dot(&w, &basis[j]);
        alphas.push(alpha);
        // w -= alpha·vj + beta·v_{j-1}
        axpy(&mut w, -alpha, &basis[j]);
        if j > 0 {
            axpy(&mut w, -betas[j - 1], &basis[j - 1]);
        }
        // full reorthogonalization (twice is enough)
        for _ in 0..2 {
            for q in &basis {
                let c = dot(&w, q);
                axpy(&mut w, -c, q);
            }
        }
        let beta = norm(&w);
        scope.report((j + 1) as u64, beta);
        if j + 1 == m {
            break;
        }
        if beta < 1e-12 {
            // invariant subspace found: restart orthogonal to the basis
            // (deterministic across ranks)
            let mut fresh = rng.normals(k_dim);
            for q in &basis {
                let c = dot(&fresh, q);
                axpy(&mut fresh, -c, q);
            }
            normalize(&mut fresh);
            betas.push(0.0);
            basis.push(fresh);
            continue;
        }
        betas.push(beta);
        for x in &mut w {
            *x /= beta;
        }
        basis.push(w);
    }

    let steps = alphas.len();
    let (theta, y) = super::tridiag::tql2(&alphas, &betas[..steps - 1])?;

    // top-k Ritz pairs (tql2 returns ascending)
    let k = opts.rank.min(steps);
    let mut sigma = Vec::with_capacity(k);
    let mut v = LocalMatrix::zeros(k_dim, k);
    // contiguous column scratch: accumulate V_kk = Σ_j y[idx][j]·basis[j]
    // with vectorizable axpys, then one strided write into the k_dim×k
    // output (the per-element get/set walk defeated vectorization)
    let mut col = vec![0.0f64; k_dim];
    for kk in 0..k {
        let idx = steps - 1 - kk;
        let lam = theta[idx].max(0.0);
        sigma.push(lam.sqrt());
        col.fill(0.0);
        for (j, q) in basis.iter().take(steps).enumerate() {
            axpy(&mut col, y[idx][j], q);
        }
        for (i, x) in col.iter().enumerate() {
            v.set(i, kk, *x);
        }
    }

    // U = A · V · Σ⁻¹ (row-distributed like A), recovered panel by
    // panel so no more than one panel of A is resident at a time
    let mut u_local = LocalMatrix::zeros(local_rows, k);
    for &s in &starts {
        let n = p.min(local_rows - s);
        let panel = a.panel(s, n)?;
        let mut u_panel = LocalMatrix::zeros(n, k);
        engine.gemm(crate::compute::GemmVariant::NN, &mut u_panel, panel.as_ref(), &v)?;
        for i in 0..n {
            let row = u_panel.row_mut(i);
            for (kk, sg) in sigma.iter().enumerate() {
                if *sg > 1e-300 {
                    row[kk] /= sg;
                }
            }
        }
        u_local.write_rows(s, &u_panel);
    }

    Ok(SvdResult { sigma, v, u_local, steps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::LocalComm;
    use crate::compute::NativeEngine;
    use crate::distmat::RowBlockLayout;

    /// Deterministic matrix with a known, well-separated spectrum:
    /// A = U·diag(σ)·Vᵀ built from Householder-orthogonalized random bases.
    fn matrix_with_spectrum(n: usize, k_dim: usize, sigmas: &[f64], seed: u64) -> LocalMatrix {
        let mut rng = Rng::new(seed);
        // crude orthogonalization of random tall matrices
        let mut u = LocalMatrix::from_fn(n, sigmas.len(), |_, _| rng.normal());
        gram_schmidt(&mut u);
        let mut v = LocalMatrix::from_fn(k_dim, sigmas.len(), |_, _| rng.normal());
        gram_schmidt(&mut v);
        let mut a = LocalMatrix::zeros(n, k_dim);
        // a += U diag(s) Vᵀ
        let mut us = u.clone();
        for i in 0..n {
            let row = us.row_mut(i);
            for (j, s) in sigmas.iter().enumerate() {
                row[j] *= s;
            }
        }
        a.gemm_nt(&us, &v);
        a
    }

    fn gram_schmidt(m: &mut LocalMatrix) {
        let (rows, cols) = (m.rows(), m.cols());
        for j in 0..cols {
            for prev in 0..j {
                let mut c = 0.0;
                for i in 0..rows {
                    c += m.get(i, j) * m.get(i, prev);
                }
                for i in 0..rows {
                    let v = m.get(i, j) - c * m.get(i, prev);
                    m.set(i, j, v);
                }
            }
            let mut nrm = 0.0;
            for i in 0..rows {
                nrm += m.get(i, j) * m.get(i, j);
            }
            let nrm = nrm.sqrt();
            for i in 0..rows {
                let v = m.get(i, j) / nrm;
                m.set(i, j, v);
            }
        }
    }

    #[test]
    fn recovers_known_spectrum_single_rank() {
        let sigmas = [10.0, 7.0, 4.0, 2.0, 1.0];
        let a = matrix_with_spectrum(60, 30, &sigmas, 5);
        let comms = LocalComm::group(1, None);
        let mut engine = NativeEngine::new();
        let res = truncated_svd(
            &comms[0],
            &mut engine,
            &a,
            &SvdOptions { rank: 3, steps: 0, seed: 1 },
        )
        .unwrap();
        for (got, want) in res.sigma.iter().zip(&sigmas[..3]) {
            assert!((got - want).abs() < 1e-6, "sigma {got} vs {want}");
        }
        // residual check: ‖A v − σ u‖ small
        let mut av = LocalMatrix::zeros(60, 3);
        av.gemm_nn(&a, &res.v);
        for kk in 0..3 {
            for i in 0..60 {
                let want = res.sigma[kk] * res.u_local.get(i, kk);
                assert!((av.get(i, kk) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn distributed_matches_serial() {
        let sigmas = [9.0, 6.0, 3.0, 1.5];
        let n = 64;
        let a = matrix_with_spectrum(n, 24, &sigmas, 6);
        let opts = SvdOptions { rank: 2, steps: 0, seed: 2 };

        let serial = {
            let comms = LocalComm::group(1, None);
            truncated_svd(&comms[0], &mut NativeEngine::new(), &a, &opts).unwrap()
        };

        for workers in [2usize, 3] {
            let layout = RowBlockLayout::even(n, 24, workers);
            let comms = LocalComm::group(workers, None);
            let mut handles = Vec::new();
            for comm in comms {
                let (ra, rb) = layout.ranges[comm.rank()];
                let local = a.slice_rows(ra, rb);
                let opts = opts.clone();
                handles.push(std::thread::spawn(move || {
                    truncated_svd(&comm, &mut NativeEngine::new(), &local, &opts).unwrap()
                }));
            }
            let results: Vec<SvdResult> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            for res in &results {
                for (g, w) in res.sigma.iter().zip(&serial.sigma) {
                    assert!((g - w).abs() < 1e-8, "workers={workers}");
                }
                // replicated V identical across ranks (up to bit equality,
                // since every rank does the same arithmetic)
                assert_eq!(res.v, results[0].v);
            }
        }
    }

    #[test]
    fn paneled_svd_matches_whole_block() {
        let sigmas = [8.0, 5.0, 2.5];
        let a = matrix_with_spectrum(48, 20, &sigmas, 9);
        let opts = SvdOptions { rank: 3, steps: 0, seed: 3 };
        let full = {
            let comms = LocalComm::group(1, None);
            truncated_svd(&comms[0], &mut NativeEngine::new(), &a, &opts).unwrap()
        };
        // one panel covering every row: identical engine calls, so every
        // output bit matches the classic path
        let one = {
            let comms = LocalComm::group(1, None);
            truncated_svd_panels(
                &comms[0],
                &mut NativeEngine::new(),
                &a,
                48,
                &opts,
                &TaskScope::detached(),
            )
            .unwrap()
        };
        assert_eq!(one.sigma, full.sigma);
        assert_eq!(one.v, full.v);
        assert_eq!(one.u_local, full.u_local);
        // 7-row panels (uneven tail): same spectrum within Lanczos
        // tolerance — only the Gram summation order differs
        let multi = {
            let comms = LocalComm::group(1, None);
            truncated_svd_panels(
                &comms[0],
                &mut NativeEngine::new(),
                &a,
                7,
                &opts,
                &TaskScope::detached(),
            )
            .unwrap()
        };
        for (g, w) in multi.sigma.iter().zip(&full.sigma) {
            assert!((g - w).abs() < 1e-8, "{g} vs {w}");
        }
        for kk in 0..3 {
            for i in 0..48 {
                let d = (multi.u_local.get(i, kk).abs()
                    - full.u_local.get(i, kk).abs())
                .abs();
                assert!(d < 1e-8, "u[{i},{kk}]");
            }
        }
    }

    #[test]
    fn rank_validation() {
        let a = LocalMatrix::zeros(4, 3);
        let comms = LocalComm::group(1, None);
        let mut e = NativeEngine::new();
        assert!(truncated_svd(&comms[0], &mut e, &a, &SvdOptions { rank: 9, steps: 0, seed: 0 }).is_err());
        assert!(truncated_svd(&comms[0], &mut e, &a, &SvdOptions { rank: 0, steps: 0, seed: 0 }).is_err());
    }
}
