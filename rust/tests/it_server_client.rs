//! Integration: full client↔server loop over real TCP sockets — the
//! paper's Figure 2 session (connect, register, send matrix, run routine,
//! materialize results, stop), using the native engine so it runs without
//! artifacts.

use alchemist::client::AlchemistContext;
use alchemist::config::{Config, EngineKind};
use alchemist::coordinator::AlchemistServer;
use alchemist::distmat::LocalMatrix;
use alchemist::protocol::{Params, Value};
use alchemist::sparklite::IndexedRowMatrix;
use alchemist::util::prng::Rng;

fn native_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.engine = EngineKind::Native;
    cfg
}

fn random_matrix(seed: u64, rows: usize, cols: usize) -> LocalMatrix {
    let mut rng = Rng::new(seed);
    LocalMatrix::from_fn(rows, cols, |_, _| rng.normal())
}

#[test]
fn figure2_qr_session() {
    let server = AlchemistServer::start(native_cfg(), 3).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &native_cfg(), 2).unwrap();
    assert_eq!(ac.num_workers(), 3);
    ac.register_library("elemental", "builtin:elemental").unwrap();

    let a = random_matrix(1, 67, 8); // awkward row count across 3 workers
    let irm = IndexedRowMatrix::from_local(&a, 4);
    let (al_a, stats) = ac.send_matrix("A", &irm).unwrap();
    assert_eq!(stats.bytes, 67 * 8 * 8);
    assert!(stats.secs > 0.0);

    let res = ac
        .run_task("elemental", "qr", Params::new().with_matrix("A", al_a.id))
        .unwrap();
    let al_q = res.output("Q").unwrap().clone();
    let al_r = res.output("R").unwrap().clone();
    assert_eq!((al_q.rows, al_q.cols), (67, 8));
    assert_eq!((al_r.rows, al_r.cols), (8, 8));
    assert!(res.timing("compute") > 0.0);
    assert!(res.timing("sim_secs") > 0.0);

    let (q, _) = ac.to_indexed_row_matrix(&al_q, 4).unwrap();
    let (r, _) = ac.to_indexed_row_matrix(&al_r, 1).unwrap();
    let q = q.to_local().unwrap();
    let r = r.to_local().unwrap();

    // A = Q·R, QᵀQ = I
    let mut qr = LocalMatrix::zeros(67, 8);
    qr.gemm_nn(&q, &r);
    assert!(qr.max_abs_diff(&a) < 1e-9, "reconstruction {}", qr.max_abs_diff(&a));
    let mut qtq = LocalMatrix::zeros(8, 8);
    qtq.gemm_tn(&q, &q);
    assert!(qtq.max_abs_diff(&LocalMatrix::identity(8)) < 1e-10);

    // handle lifecycle
    let listed = ac.list_matrices().unwrap();
    assert!(listed.iter().any(|(id, ..)| *id == al_a.id));
    ac.free(&al_a).unwrap();
    let listed = ac.list_matrices().unwrap();
    assert!(!listed.iter().any(|(id, ..)| *id == al_a.id));

    ac.stop();
    server.shutdown();
}

#[test]
fn cg_solve_via_server_matches_local_reference() {
    let server = AlchemistServer::start(native_cfg(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &native_cfg(), 2).unwrap();
    ac.register_library("skylark", "builtin:skylark").unwrap();

    let x = random_matrix(2, 50, 12);
    let y = random_matrix(3, 50, 4);
    let (al_x, _) = ac.send_matrix("X", &IndexedRowMatrix::from_local(&x, 3)).unwrap();
    let (al_y, _) = ac.send_matrix("Y", &IndexedRowMatrix::from_local(&y, 3)).unwrap();

    let res = ac
        .run_task(
            "skylark",
            "cg_solve",
            Params::new()
                .with_matrix("X", al_x.id)
                .with_matrix("Y", al_y.id)
                .with_f64("lambda", 1e-3)
                .with_f64("tol", 1e-12)
                .with_i64("max_iters", 300),
        )
        .unwrap();
    let al_w = res.output("W").unwrap().clone();
    let iters = res.scalars.i64("iters").unwrap();
    assert!(iters > 1);
    match res.scalars.get("iter_secs") {
        Some(Value::F64s(v)) => assert_eq!(v.len(), iters as usize),
        other => panic!("iter_secs missing: {other:?}"),
    }

    let (w, _) = ac.to_indexed_row_matrix(&al_w, 1).unwrap();
    let w = w.to_local().unwrap();

    // reference: in-process solver
    let comms = alchemist::collectives::LocalComm::group(1, None);
    let mut e = alchemist::compute::NativeEngine::new();
    let want = alchemist::linalg::cg_solve(
        &comms[0],
        &mut e,
        &x,
        &y,
        50,
        &alchemist::linalg::CgOptions { lambda: 1e-3, tol: 1e-12, max_iters: 300 },
    )
    .unwrap();
    assert!(w.max_abs_diff(&want.w) < 1e-8, "diff {}", w.max_abs_diff(&want.w));

    ac.shutdown_server().unwrap();
    server.shutdown_on_request();
}

#[test]
fn chained_routines_via_handles() {
    // rand_matrix -> fro_norm -> replicate_cols -> fro_norm: handles flow
    // between routines without any client-side data movement
    let server = AlchemistServer::start(native_cfg(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &native_cfg(), 1).unwrap();
    ac.register_library("elemental", "builtin:elemental").unwrap();

    let res = ac
        .run_task(
            "elemental",
            "rand_matrix",
            Params::new().with_i64("rows", 40).with_i64("cols", 6).with_i64("seed", 9),
        )
        .unwrap();
    let a = res.output("A").unwrap().clone();

    let n1 = ac
        .run_task("elemental", "fro_norm", Params::new().with_matrix("A", a.id))
        .unwrap()
        .scalars
        .f64("norm")
        .unwrap();
    assert!(n1 > 0.0);

    let rep = ac
        .run_task(
            "elemental",
            "replicate_cols",
            Params::new().with_matrix("A", a.id).with_i64("times", 4),
        )
        .unwrap();
    let arep = rep.output("A_rep").unwrap().clone();
    assert_eq!(arep.cols, 24);

    let n2 = ac
        .run_task("elemental", "fro_norm", Params::new().with_matrix("A", arep.id))
        .unwrap()
        .scalars
        .f64("norm")
        .unwrap();
    assert!((n2 - 2.0 * n1).abs() < 1e-9, "replication-x4 doubles the norm: {n1} {n2}");

    ac.stop();
    server.shutdown();
}

#[test]
fn error_paths_are_reported_not_fatal() {
    let server = AlchemistServer::start(native_cfg(), 2).unwrap();
    let mut ac = AlchemistContext::connect(&server.control_addr, &native_cfg(), 1).unwrap();

    // unregistered library
    let err = ac.run_task("skylark", "cg_solve", Params::new()).unwrap_err();
    assert!(err.to_string().contains("not registered"), "{err}");

    // unknown routine
    ac.register_library("skylark", "builtin:skylark").unwrap();
    let err = ac.run_task("skylark", "nope", Params::new()).unwrap_err();
    assert!(err.to_string().contains("no routine"), "{err}");

    // bad library path
    let err = ac.register_library("x", "/lib/foo.so").unwrap_err();
    assert!(err.to_string().contains("builtin"), "{err}");

    // unknown handle
    let err = ac
        .run_task(
            "skylark",
            "cg_solve",
            Params::new().with_matrix("X", 999).with_matrix("Y", 998),
        )
        .unwrap_err();
    assert!(err.to_string().contains("not found"), "{err}");

    // the session survives all of the above
    let listed = ac.list_matrices().unwrap();
    assert!(listed.is_empty());

    ac.stop();
    server.shutdown();
}

#[test]
fn seal_with_missing_rows_fails_and_session_survives() {
    use alchemist::net::Framed;
    use alchemist::protocol::{ControlMsg, PROTOCOL_VERSION};

    let server = AlchemistServer::start(native_cfg(), 2).unwrap();
    let cfg = native_cfg();
    let mut control = Framed::connect(&server.control_addr, cfg.transfer.buf_bytes).unwrap();
    let reply = control
        .call(&ControlMsg::Handshake {
            client_name: "t".into(),
            version: PROTOCOL_VERSION,
            request_workers: 0,
            rows_per_frame: 0,
            buf_bytes: 0,
            priority: alchemist::protocol::DEFAULT_PRIORITY,
        })
        .unwrap();
    assert!(matches!(reply, ControlMsg::HandshakeAck { .. }));
    // create a 10-row matrix but push nothing
    let created = control
        .call(&ControlMsg::CreateMatrix { name: "X".into(), rows: 10, cols: 2 })
        .unwrap();
    let id = match created {
        ControlMsg::MatrixCreated { id, .. } => id,
        other => panic!("{other:?}"),
    };
    let err = control.call(&ControlMsg::SealMatrix { id }).unwrap_err();
    assert!(err.to_string().contains("sealed with 0 of 10"), "{err}");
    // session still works afterwards
    let listed = control.call(&ControlMsg::ListMatrices).unwrap();
    assert!(matches!(listed, ControlMsg::MatrixList { .. }));
    server.shutdown();
}

#[test]
fn data_plane_rejects_bad_pushes_and_unsealed_pulls() {
    use alchemist::net::Framed;
    use alchemist::protocol::{ControlMsg, DataMsg, PROTOCOL_VERSION};

    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    let mut control = Framed::connect(&server.control_addr, 1 << 16).unwrap();
    let ack = control
        .call(&ControlMsg::Handshake {
            client_name: "t".into(),
            version: PROTOCOL_VERSION,
            request_workers: 0,
            rows_per_frame: 0,
            buf_bytes: 0,
            priority: alchemist::protocol::DEFAULT_PRIORITY,
        })
        .unwrap();
    let worker_addrs = match ack {
        ControlMsg::HandshakeAck { worker_addrs, .. } => worker_addrs,
        other => panic!("{other:?}"),
    };
    let created = control
        .call(&ControlMsg::CreateMatrix { name: "X".into(), rows: 10, cols: 2 })
        .unwrap();
    let id = match created {
        ControlMsg::MatrixCreated { id, .. } => id,
        other => panic!("{other:?}"),
    };

    let mut data = Framed::connect(&worker_addrs[0], 1 << 16).unwrap();
    data.send_data_flush(&DataMsg::DataHandshake {
        session_id: 1,
        executor_id: 0,
        rows_per_frame: 0,
    })
    .unwrap();
    assert!(matches!(data.recv_data().unwrap(), DataMsg::DataHandshakeAck { .. }));

    // pull before sealing -> error
    data.send_data_flush(&DataMsg::PullRows {
        matrix_id: id,
        start_row: 0,
        nrows: 1,
        start_col: 0,
        sel_cols: 0,
    })
        .unwrap();
    match data.recv_data().unwrap() {
        DataMsg::DataError { message } => assert!(message.contains("not sealed"), "{message}"),
        other => panic!("{other:?}"),
    }

    // push to an unknown matrix -> error
    data.send_data_flush(&DataMsg::PushRows {
        matrix_id: 999,
        start_row: 0,
        nrows: 1,
        ncols: 2,
        data: vec![1.0, 2.0],
    })
    .unwrap();
    match data.recv_data().unwrap() {
        DataMsg::DataError { message } => assert!(message.contains("not found"), "{message}"),
        other => panic!("{other:?}"),
    }

    // push rows owned by the OTHER worker -> error
    data.send_data_flush(&DataMsg::PushRows {
        matrix_id: id,
        start_row: 9,
        nrows: 1,
        ncols: 2,
        data: vec![1.0, 2.0],
    })
    .unwrap();
    match data.recv_data().unwrap() {
        DataMsg::DataError { message } => {
            assert!(message.contains("outside rank"), "{message}")
        }
        other => panic!("{other:?}"),
    }
    server.shutdown();
}

#[test]
fn executor_disconnect_mid_push_leaves_matrix_unsealed_not_poisoned() {
    use alchemist::net::Framed;
    use alchemist::protocol::{ControlMsg, DataMsg, PROTOCOL_VERSION};

    let cfg = native_cfg();
    let server = AlchemistServer::start(cfg.clone(), 2).unwrap();
    // worker groups are exclusive now: split the 2-worker pool so this
    // context and the hand-rolled session below can coexist
    let mut ac =
        AlchemistContext::connect_with_workers(&server.control_addr, &cfg, 1, 1).unwrap();
    assert_eq!(ac.granted_workers, 1);

    // half-push by hand, then drop the socket
    let mut control = Framed::connect(&server.control_addr, 1 << 16).unwrap();
    let ack = control
        .call(&ControlMsg::Handshake {
            client_name: "t2".into(),
            version: PROTOCOL_VERSION,
            request_workers: 1,
            rows_per_frame: 0,
            buf_bytes: 0,
            priority: alchemist::protocol::DEFAULT_PRIORITY,
        })
        .unwrap();
    let (session_id, worker_addrs) = match ack {
        ControlMsg::HandshakeAck { session_id, worker_addrs, .. } => {
            (session_id, worker_addrs)
        }
        other => panic!("{other:?}"),
    };
    let created = control
        .call(&ControlMsg::CreateMatrix { name: "H".into(), rows: 4, cols: 1 })
        .unwrap();
    let id = match created {
        ControlMsg::MatrixCreated { id, .. } => id,
        other => panic!("{other:?}"),
    };
    {
        let mut data = Framed::connect(&worker_addrs[0], 1 << 16).unwrap();
        data.send_data_flush(&DataMsg::DataHandshake {
            session_id,
            executor_id: 0,
            rows_per_frame: 0,
        })
        .unwrap();
        assert!(matches!(data.recv_data().unwrap(), DataMsg::DataHandshakeAck { .. }));
        data.send_data_flush(&DataMsg::PushRows {
            matrix_id: id,
            start_row: 0,
            nrows: 1,
            ncols: 1,
            data: vec![1.0],
        })
        .unwrap();
        // dropped here: disconnect without PushDone
    }
    // (no ack on streamed PushRows, so the row may or may not have landed
    // before the seal races it — either way sealing must fail short)
    let err = control.call(&ControlMsg::SealMatrix { id }).unwrap_err();
    assert!(err.to_string().contains("sealed with"), "{err}");

    // the server is still healthy: a fresh full transfer succeeds
    let m = random_matrix(9, 8, 2);
    let (al, _) = ac.send_matrix("ok", &IndexedRowMatrix::from_local(&m, 2)).unwrap();
    let (back, _) = ac.to_indexed_row_matrix(&al, 2).unwrap();
    assert_eq!(back.to_local().unwrap(), m);
    server.shutdown();
}

#[test]
fn concurrent_sessions_supported() {
    let server = AlchemistServer::start(native_cfg(), 2).unwrap();
    let addr = server.control_addr.clone();
    let mut handles = Vec::new();
    for seed in 0..3u64 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut ac = AlchemistContext::connect(&addr, &native_cfg(), 1).unwrap();
            ac.register_library("elemental", "builtin:elemental").unwrap();
            let x = random_matrix(seed, 30, 4);
            let (al, _) =
                ac.send_matrix("X", &IndexedRowMatrix::from_local(&x, 2)).unwrap();
            let (back, _) = ac.to_indexed_row_matrix(&al, 2).unwrap();
            assert_eq!(back.to_local().unwrap(), x);
            ac.stop();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    server.shutdown();
}
