//! # Alchemist (KDD 2018) — rust + JAX/Pallas reproduction
//!
//! Alchemist is an *offloading bridge*: a Spark-like host framework hands
//! large dense matrices to an HPC-style server over TCP sockets, the server
//! runs MPI-library-style distributed linear algebra on them (block CG,
//! truncated SVD, QR, random-feature expansion), and ships results back as
//! matrix handles the client can materialize on demand.
//!
//! The crate is organised bottom-up:
//!
//! * substrates — [`util`], [`config`], [`metrics`], [`protocol`], [`net`],
//!   [`collectives`] (the MPI stand-in), [`distmat`] (the Elemental
//!   stand-in), [`sparklite`] (the Spark stand-in), [`hdf5sim`];
//! * compute — [`compute`] engines backed by [`runtime`] (AOT-compiled
//!   JAX/Pallas artifacts over a PJRT stand-in) or a native blocked GEMM
//!   with runtime ISA dispatch ([`simd`]), selected per call by
//!   [`compute::dispatch`] when `engine = "auto"`;
//! * numerics — [`linalg`] (the libSkylark / ARPACK stand-ins);
//! * the paper's system — [`coordinator`] (server, driver, workers, matrix
//!   handles, library registry) and [`client`] (the Alchemist-Client
//!   Interface of §3.1.2);
//! * experiment support — [`workloads`], [`testkit`].
//!
//! ## Sessions & worker groups
//!
//! The coordinator is a concurrent multi-tenant scheduler: each client
//! handshake negotiates a worker-group size (the paper's
//! `requestWorkers`), a FIFO admission queue grants an *exclusive* subset
//! of the worker pool, and the session's tasks run SPMD over that group's
//! own [`collectives::LocalComm::subgroup`] communicator. Sessions on
//! disjoint groups execute concurrently; requests exceeding free capacity
//! queue (bounded by `scheduler.queue_timeout_s`); matrix handles are
//! namespaced per session so teardown frees one tenant's state without
//! touching the others. See [`config::SchedulerConfig`] for the policy
//! knobs and `tests/it_sessions.rs` for the observable guarantees.
//!
//! ## Asynchronous tasks (protocol v4)
//!
//! Task execution is non-blocking: [`client::AlchemistContext::submit`]
//! returns a [`client::TaskHandle`] with `status()` / `wait()` /
//! `cancel()`; server-side each session owns a bounded FIFO task queue
//! and a dispatcher thread, iterative routines observe a cooperative
//! cancel token and report per-iteration progress through a
//! [`tasks::TaskScope`], and the classic blocking `run_task` survives as
//! submit + wait. `docs/tasks.md` documents the state machine, the wire
//! messages, and the cancellation contract routine authors must follow;
//! `tests/it_tasks.rs` pins the lifecycle edges.
//!
//! See `DESIGN.md` for the substitution table (what the paper ran on Cori
//! vs. what this repo builds) and the experiment index mapping Tables 1–5
//! and Figure 3 to `rust/benches/`.

// Lint posture (CI runs `cargo clippy -- -D warnings`): correctness,
// suspicious, and perf lints stay hot; these stylistic ones are allowed
// because the paper-shaped code trips them by design — explicit index
// loops in the GEMM/tile kernels, many-argument SPMD routine signatures,
// and socket read/write type pairs.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod cli;
pub mod client;
pub mod collectives;
pub mod compute;
pub mod config;
pub mod coordinator;
pub mod distmat;
pub mod hdf5sim;
pub mod linalg;
pub mod logging;
pub mod metrics;
pub mod net;
pub mod protocol;
pub mod runtime;
pub mod simd;
pub mod sparklite;
pub mod tasks;
pub mod testkit;
pub mod util;
pub mod workloads;

/// Crate-wide result type (anyhow-backed; module-specific errors in
/// [`protocol::ProtocolError`] etc. convert into it).
pub type Result<T> = anyhow::Result<T>;
