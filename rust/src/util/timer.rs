//! Wall-clock timing helpers.

use std::time::{Duration, Instant};

/// Measure one closure; returns (result, elapsed seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// CPU seconds consumed by the *calling thread* so far.
///
/// This is the honest per-rank "busy time" on a box where worker threads
/// time-slice one core: wallclock inside a task includes time spent
/// descheduled while sibling ranks run, but thread CPU time does not. The
/// SimClock uses `max` over ranks of this to reconstruct what the same
/// SPMD region would cost with one core per rank (DESIGN.md §2).
pub fn thread_cpu_secs() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // Safety: plain syscall filling the struct we own.
    let rc = unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts)
    };
    if rc != 0 {
        return 0.0;
    }
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Measure one closure's thread-CPU cost; returns (result, cpu seconds).
pub fn time_cpu<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let c0 = thread_cpu_secs();
    let out = f();
    (out, (thread_cpu_secs() - c0).max(0.0))
}

/// A resettable stopwatch accumulating named laps (used by the driver to
/// break a routine into the paper's columns: transfer / compute / return).
#[derive(Debug, Default)]
pub struct Stopwatch {
    laps: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin a lap, ending any lap in progress.
    pub fn start(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// End the lap in progress (no-op if none).
    pub fn stop(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            self.laps.push((name, t0.elapsed()));
        }
    }

    /// Seconds accumulated under `name` across all laps.
    pub fn secs(&self, name: &str) -> f64 {
        self.laps
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, d)| d.as_secs_f64())
            .sum()
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }

    pub fn total_secs(&self) -> f64 {
        self.laps.iter().map(|(_, d)| d.as_secs_f64()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates_named_laps() {
        let mut sw = Stopwatch::new();
        sw.start("a");
        std::thread::sleep(Duration::from_millis(5));
        sw.start("b"); // implicitly stops "a"
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        sw.start("a");
        sw.stop();
        assert!(sw.secs("a") >= 0.004);
        assert!(sw.secs("b") >= 0.004);
        assert!(sw.secs("missing") == 0.0);
        assert_eq!(sw.laps().len(), 3);
        assert!(sw.total_secs() >= sw.secs("a") + sw.secs("b") - 1e-9);
    }
}
