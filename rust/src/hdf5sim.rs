//! Minimal binary matrix container — the HDF5 stand-in (DESIGN.md §2).
//!
//! The ocean experiments (Table 5 / Figure 3) compare loading the data in
//! Spark vs. loading it directly in Alchemist from HDF5. What matters is
//! the *path* (file → worker shards without a trip through the client);
//! the format is a 32-byte header + row-major f64 payload, and workers can
//! read their row ranges independently (`read_rows`), which is the
//! parallel-read property the experiment leans on.
//!
//! Layout (all little-endian):
//! `magic "ALCH5SIM" | version u32 | reserved u32 | rows u64 | cols u64 |
//!  payload rows*cols*8 bytes`.
//!
//! Two read paths:
//!
//! * [`read_rows`] — seek + buffered read into a heap [`LocalMatrix`]
//!   (works everywhere, converts on big-endian hosts);
//! * [`MappedMatrix`] — the v7 direct-ingest path: the file is `mmap`ed
//!   read-only and the payload viewed in place as `&[f64]`, so a worker's
//!   shard of a `LoadMatrix` ingest occupies no heap at all and pull
//!   replies stream file bytes from the page cache straight into
//!   `writev` (see `docs/storage.md`). Only available on little-endian
//!   unix hosts — everywhere else [`MappedMatrix::open`] returns a clean
//!   error and callers fall back to [`read_rows`] (which converts), so a
//!   big-endian host can never misread the little-endian payload.
//!
//! Writers go through [`write_payload_le`], never a native-endian
//! `f64 → u8` transmute: the header doc above promises little-endian
//! bytes on disk, and the seed's bulk write silently broke that promise
//! on big-endian hosts.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::Context;

use crate::distmat::LocalMatrix;

const MAGIC: &[u8; 8] = b"ALCH5SIM";
const VERSION: u32 = 1;
/// Header size: magic(8) + version(4) + reserved(4) + rows(8) + cols(8).
pub const HEADER_BYTES: u64 = 8 + 4 + 4 + 8 + 8;

/// Write `xs` to `w` as little-endian bytes: one bulk write on
/// little-endian targets, per-element conversion on big-endian ones.
fn write_payload_le(w: &mut impl Write, xs: &[f64]) -> std::io::Result<()> {
    #[cfg(target_endian = "little")]
    {
        w.write_all(crate::protocol::wire::f64s_as_le_bytes(xs))
    }
    #[cfg(target_endian = "big")]
    {
        for x in xs {
            w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
}

fn write_header(w: &mut impl Write, rows: usize, cols: usize) -> std::io::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&0u32.to_le_bytes())?;
    w.write_all(&(rows as u64).to_le_bytes())?;
    w.write_all(&(cols as u64).to_le_bytes())?;
    Ok(())
}

/// Write a matrix to `path`.
pub fn write_matrix(path: &Path, m: &LocalMatrix) -> crate::Result<()> {
    let mut w = Writer::create(path, m.rows(), m.cols())?;
    w.append(m)?;
    w.finish()
}

/// Incremental writer: header up front, then row chunks in order. This is
/// how datasets larger than RAM are authored (`OceanSpec::write_file`
/// generates and appends one bounded chunk at a time).
pub struct Writer {
    w: BufWriter<File>,
    rows: usize,
    cols: usize,
    written_rows: usize,
}

impl Writer {
    pub fn create(path: &Path, rows: usize, cols: usize) -> crate::Result<Self> {
        let file = File::create(path).with_context(|| format!("creating {path:?}"))?;
        let mut w = BufWriter::with_capacity(1 << 20, file);
        write_header(&mut w, rows, cols)?;
        Ok(Writer { w, rows, cols, written_rows: 0 })
    }

    /// Append the next chunk of rows (must arrive in order, widths equal).
    pub fn append(&mut self, chunk: &LocalMatrix) -> crate::Result<()> {
        anyhow::ensure!(chunk.cols() == self.cols, "chunk width mismatch");
        anyhow::ensure!(
            self.written_rows + chunk.rows() <= self.rows,
            "chunk overflows the declared {} rows",
            self.rows
        );
        write_payload_le(&mut self.w, chunk.data())?;
        self.written_rows += chunk.rows();
        Ok(())
    }

    /// Flush and verify every declared row landed.
    pub fn finish(mut self) -> crate::Result<()> {
        anyhow::ensure!(
            self.written_rows == self.rows,
            "wrote {} of {} declared rows",
            self.written_rows,
            self.rows
        );
        self.w.flush()?;
        Ok(())
    }
}

/// Matrix dimensions from the header.
pub fn read_header(path: &Path) -> crate::Result<(usize, usize)> {
    let file = File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("reading magic")?;
    anyhow::ensure!(&magic == MAGIC, "{path:?} is not an ALCH5SIM file");
    let mut u32buf = [0u8; 4];
    r.read_exact(&mut u32buf)?;
    anyhow::ensure!(
        u32::from_le_bytes(u32buf) == VERSION,
        "unsupported ALCH5SIM version"
    );
    r.read_exact(&mut u32buf)?; // reserved
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let rows = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let cols = u64::from_le_bytes(u64buf) as usize;
    Ok((rows, cols))
}

/// Header dims plus a whole-file integrity check: the byte length on disk
/// must match `HEADER_BYTES + rows·cols·8` exactly. `LoadMatrix` calls
/// this *before* any worker registers a block, so a truncated or padded
/// file is rejected up front instead of surfacing as a short read (or a
/// short mmap → SIGBUS) on one rank mid-ingest.
pub fn validate(path: &Path) -> crate::Result<(usize, usize)> {
    let (rows, cols) = read_header(path)?;
    let payload = (rows as u64)
        .checked_mul(cols as u64)
        .and_then(|e| e.checked_mul(8))
        .ok_or_else(|| anyhow::anyhow!("{path:?} header dims overflow"))?;
    let want = HEADER_BYTES + payload;
    let got = std::fs::metadata(path)?.len();
    anyhow::ensure!(
        got == want,
        "{path:?} is corrupt: {got} bytes on disk, header declares {rows}x{cols} ({want} bytes)"
    );
    Ok((rows, cols))
}

/// Read rows `[start, end)` — workers call this concurrently with their
/// own ranges (independent file handles, seek + sequential read).
pub fn read_rows(path: &Path, start: usize, end: usize) -> crate::Result<LocalMatrix> {
    let (rows, cols) = read_header(path)?;
    anyhow::ensure!(start <= end && end <= rows, "row range out of bounds");
    let mut file = File::open(path)?;
    file.seek(SeekFrom::Start(HEADER_BYTES + (start * cols * 8) as u64))?;
    let mut data = vec![0f64; (end - start) * cols];
    // Safety: filling the f64 buffer through its byte view.
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, data.len() * 8)
    };
    let mut r = BufReader::with_capacity(1 << 20, file);
    r.read_exact(bytes).context("reading row payload")?;
    // the wire bytes are little-endian by contract; swap on BE hosts
    #[cfg(target_endian = "big")]
    for x in &mut data {
        *x = f64::from_bits(x.to_bits().swap_bytes());
    }
    Ok(LocalMatrix::from_data(end - start, cols, data))
}

/// Read the whole matrix.
pub fn read_matrix(path: &Path) -> crate::Result<LocalMatrix> {
    let (rows, _) = read_header(path)?;
    read_rows(path, 0, rows)
}

// ---- mmap-backed open path (v7 direct ingest) ----

/// A read-only memory mapping of an ALCH5SIM file whose payload is viewed
/// in place as `&[f64]`.
///
/// The mapping is page-cache-backed: touching the slice faults pages in,
/// and the kernel evicts them under memory pressure — which is exactly
/// the out-of-core property `LoadMatrix` blocks need (`docs/storage.md`).
/// Dropping the value unmaps.
///
/// Only constructible on little-endian unix hosts (the in-place `&[f64]`
/// view is only correct when file byte order == native byte order); on
/// any other host [`MappedMatrix::open`] fails cleanly and callers take
/// the converting [`read_rows`] fallback.
pub struct MappedMatrix {
    base: *mut u8,
    map_len: usize,
    rows: usize,
    cols: usize,
}

// Safety: the mapping is read-only (PROT_READ) for its whole lifetime and
// the raw pointer is never handed out mutably; concurrent readers on any
// thread see immutable file bytes.
unsafe impl Send for MappedMatrix {}
unsafe impl Sync for MappedMatrix {}

#[cfg(unix)]
mod sys {
    //! Direct glibc/libSystem bindings for the two calls we need. The
    //! vendor set has no `libc` crate; every unix Rust binary already
    //! links the platform C library, so declaring the symbols is enough.
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub fn map_failed(p: *mut c_void) -> bool {
        p as isize == -1
    }
}

impl MappedMatrix {
    /// Map `path` read-only and validate it end to end (header, version,
    /// exact byte length, payload alignment).
    #[cfg(all(unix, target_endian = "little"))]
    pub fn open(path: &Path) -> crate::Result<Self> {
        use std::os::unix::io::AsRawFd;

        let (rows, cols) = validate(path)?;
        let map_len = (HEADER_BYTES as usize) + rows * cols * 8;
        let file = File::open(path).with_context(|| format!("opening {path:?}"))?;
        // Safety: len > 0 (header is non-empty), fd is a live open file,
        // and we claim the returned region for exactly `map_len` bytes
        // until munmap in Drop.
        let base = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                map_len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if sys::map_failed(base) {
            anyhow::bail!("mmap of {path:?} ({map_len} bytes) failed");
        }
        // fd can close now; the mapping keeps the file content reachable
        drop(file);
        let base = base as *mut u8;
        // page-aligned base + 32-byte header keeps the payload 8-aligned;
        // assert rather than assume so a format change can't create a UB
        // f64 view
        if (base as usize + HEADER_BYTES as usize) % std::mem::align_of::<f64>() != 0 {
            // Safety: unmapping the region we just mapped.
            unsafe { sys::munmap(base as *mut _, map_len) };
            anyhow::bail!("mmap of {path:?} left the payload misaligned for f64");
        }
        Ok(MappedMatrix { base, map_len, rows, cols })
    }

    /// Non-mappable hosts (non-unix, or big-endian where the in-place view
    /// would misread): fail cleanly so callers fall back to [`read_rows`].
    #[cfg(not(all(unix, target_endian = "little")))]
    pub fn open(path: &Path) -> crate::Result<Self> {
        let _ = path;
        anyhow::bail!(
            "mmap-backed ingest requires a little-endian unix host; \
             falling back to buffered reads"
        )
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The whole payload as f64s, in place (no copy).
    pub fn data(&self) -> &[f64] {
        // Safety: open() validated length and alignment; the region stays
        // mapped and read-only until Drop, and `&self` borrows it.
        unsafe {
            std::slice::from_raw_parts(
                self.base.add(HEADER_BYTES as usize) as *const f64,
                self.rows * self.cols,
            )
        }
    }

    /// Rows `[start, end)` as an in-place slice.
    pub fn row_span(&self, start: usize, end: usize) -> crate::Result<&[f64]> {
        anyhow::ensure!(start <= end && end <= self.rows, "row range out of bounds");
        Ok(&self.data()[start * self.cols..end * self.cols])
    }

    /// Payload bytes (for accounting; none of them are heap).
    pub fn payload_bytes(&self) -> u64 {
        (self.rows as u64) * (self.cols as u64) * 8
    }
}

impl Drop for MappedMatrix {
    fn drop(&mut self) {
        #[cfg(unix)]
        if self.map_len > 0 {
            // Safety: exactly the region open() mapped.
            unsafe { sys::munmap(self.base as *mut _, self.map_len) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("alchemist-hdf5sim-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_and_ranged_reads() {
        let mut rng = Rng::new(4);
        let m = LocalMatrix::from_fn(37, 5, |_, _| rng.normal());
        let path = tmp("roundtrip.bin");
        write_matrix(&path, &m).unwrap();
        assert_eq!(read_header(&path).unwrap(), (37, 5));
        assert_eq!(validate(&path).unwrap(), (37, 5));
        assert_eq!(read_matrix(&path).unwrap(), m);
        assert_eq!(read_rows(&path, 10, 20).unwrap(), m.slice_rows(10, 20));
        assert_eq!(read_rows(&path, 0, 0).unwrap().rows(), 0);
    }

    #[test]
    fn payload_bytes_are_little_endian_on_disk() {
        // the on-disk contract, independent of host endianness: payload
        // byte i*8.. is to_le_bytes of element i
        let m = LocalMatrix::from_data(1, 3, vec![1.5, -2.25, 1e300]);
        let path = tmp("le.bin");
        write_matrix(&path, &m).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let payload = &bytes[HEADER_BYTES as usize..];
        for (i, x) in m.data().iter().enumerate() {
            assert_eq!(&payload[i * 8..(i + 1) * 8], &x.to_le_bytes());
        }
    }

    #[test]
    fn chunked_writer_matches_one_shot() {
        let mut rng = Rng::new(9);
        let m = LocalMatrix::from_fn(23, 4, |_, _| rng.normal());
        let one = tmp("one-shot.bin");
        write_matrix(&one, &m).unwrap();
        let chunked = tmp("chunked.bin");
        let mut w = Writer::create(&chunked, 23, 4).unwrap();
        for (a, b) in [(0usize, 10usize), (10, 11), (11, 23)] {
            w.append(&m.slice_rows(a, b)).unwrap();
        }
        w.finish().unwrap();
        assert_eq!(std::fs::read(&one).unwrap(), std::fs::read(&chunked).unwrap());
    }

    #[test]
    fn chunked_writer_enforces_declared_rows() {
        let path = tmp("short.bin");
        let mut w = Writer::create(&path, 5, 2).unwrap();
        w.append(&LocalMatrix::zeros(3, 2)).unwrap();
        assert!(w.finish().is_err()); // 3 of 5 rows
        let mut w = Writer::create(&path, 5, 2).unwrap();
        assert!(w.append(&LocalMatrix::zeros(6, 2)).is_err()); // overflow
        let mut w = Writer::create(&path, 5, 2).unwrap();
        assert!(w.append(&LocalMatrix::zeros(5, 3)).is_err()); // width
    }

    #[test]
    fn concurrent_shard_reads_cover_matrix() {
        let mut rng = Rng::new(5);
        let m = LocalMatrix::from_fn(100, 3, |_, _| rng.normal());
        let path = tmp("shards.bin");
        write_matrix(&path, &m).unwrap();
        let ranges = crate::util::even_ranges(100, 4);
        let handles: Vec<_> = ranges
            .iter()
            .map(|&(a, b)| {
                let p = path.clone();
                std::thread::spawn(move || read_rows(&p, a, b).unwrap())
            })
            .collect();
        let mut rebuilt = LocalMatrix::zeros(100, 3);
        for (h, &(a, _)) in handles.into_iter().zip(&ranges) {
            rebuilt.write_rows(a, &h.join().unwrap());
        }
        assert_eq!(rebuilt, m);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.bin");
        std::fs::write(&path, b"definitely not a matrix").unwrap();
        assert!(read_header(&path).is_err());
        let path2 = tmp("missing-range.bin");
        write_matrix(&path2, &LocalMatrix::zeros(3, 2)).unwrap();
        assert!(read_rows(&path2, 2, 5).is_err());
    }

    #[test]
    fn validate_rejects_truncated_and_padded_files() {
        let mut rng = Rng::new(6);
        let m = LocalMatrix::from_fn(8, 4, |_, _| rng.normal());
        let path = tmp("truncated.bin");
        write_matrix(&path, &m).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 8]).unwrap();
        assert!(validate(&path).unwrap_err().to_string().contains("corrupt"));
        let mut padded = full.clone();
        padded.extend_from_slice(&[0u8; 8]);
        std::fs::write(&path, &padded).unwrap();
        assert!(validate(&path).is_err());
        std::fs::write(&path, &full).unwrap();
        assert_eq!(validate(&path).unwrap(), (8, 4));
    }

    #[cfg(all(unix, target_endian = "little"))]
    #[test]
    fn mapped_view_matches_buffered_read() {
        let mut rng = Rng::new(7);
        let m = LocalMatrix::from_fn(33, 6, |_, _| rng.normal());
        let path = tmp("mapped.bin");
        write_matrix(&path, &m).unwrap();
        let map = MappedMatrix::open(&path).unwrap();
        assert_eq!((map.rows(), map.cols()), (33, 6));
        assert_eq!(map.data(), m.data());
        assert_eq!(map.row_span(5, 12).unwrap(), &m.data()[5 * 6..12 * 6]);
        assert!(map.row_span(30, 34).is_err());
        assert_eq!(map.payload_bytes(), 33 * 6 * 8);
    }

    #[cfg(all(unix, target_endian = "little"))]
    #[test]
    fn mapped_open_rejects_truncated_file() {
        let path = tmp("mapped-truncated.bin");
        write_matrix(&path, &LocalMatrix::zeros(4, 4)).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 1]).unwrap();
        assert!(MappedMatrix::open(&path).is_err());
    }
}
