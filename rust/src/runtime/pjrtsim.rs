//! Simulated PJRT backend: executes manifest artifacts with in-process
//! rust kernels.
//!
//! The growth plan originally bound the runtime to a PJRT FFI crate,
//! which is not in the offline vendor set — the same situation as HDF5
//! (`hdf5sim`) and Spark (`sparklite`), and it gets the same treatment: a
//! stand-in that preserves the *interface shape* the engine layer was
//! designed against. Concretely this module mirrors the three PJRT
//! touch-points `Runtime` uses:
//!
//! * a process-wide [`Client`] ([`Client::cpu`]);
//! * compile-once / execute-many [`LoadedExecutable`]s with static
//!   shapes — compilation derives the computation from the manifest
//!   entry's `op` + shape tuple (the `.hlo.txt` payloads are provenance,
//!   not interpreted), and an unknown op fails at *compile* time exactly
//!   as a malformed HLO module would;
//! * device-resident [`Buffer`]s for upload-once operands (here "device"
//!   is host memory, so upload is one copy and execution reads in place).
//!
//! Semantics per op (all f64, row-major, shapes from the manifest):
//!
//! | op            | dims         | inputs → outputs                      |
//! |---------------|--------------|---------------------------------------|
//! | `gemm_nn`     | m,n,k        | c, a, b → c + a·b                     |
//! | `gemm_tn`     | m,n,k        | c, a (k×m), b → c + aᵀ·b              |
//! | `gemm_nt`     | m,n,k        | c, a, b (n×k) → c + a·bᵀ              |
//! | `gram_matvec` | pm,pk,pc     | panel, v, reg → panelᵀ(panel·v)+reg·v |
//! | `rff_expand`  | pm,pk0,pd    | x, Ω, bias, scale → scale·cos(xΩ+bias)|
//! | `cg_update`   | pm,pc        | x, r, p, q, α → x+α⊙p, r−α⊙q          |
//!
//! The matmuls run through the packed single-thread kernels
//! ([`crate::distmat::dense::gemm_slices`]), so the stand-in's throughput
//! is the realistic single-stream rate the `engine = "auto"` cost model
//! assumes (`compute::dispatch`), not a strawman triple loop.

use crate::distmat::dense::gemm_slices;

use super::manifest::ArtifactEntry;
use super::Tensor;

/// Stand-in for the PJRT CPU client.
pub struct Client;

impl Client {
    pub fn cpu() -> crate::Result<Client> {
        Ok(Client)
    }

    /// "Compile" an artifact: validate that the op is known and that the
    /// manifest's input/output shapes are consistent with its dims tuple.
    pub fn compile(&self, entry: &ArtifactEntry) -> crate::Result<LoadedExecutable> {
        validate(entry)?;
        Ok(LoadedExecutable { entry: entry.clone() })
    }
}

/// A compiled artifact: static shapes, executed many times.
pub struct LoadedExecutable {
    entry: ArtifactEntry,
}

/// A device-resident operand (upload-once, execute-many).
pub struct Buffer {
    pub(super) data: Vec<f64>,
}

fn validate(e: &ArtifactEntry) -> crate::Result<()> {
    let (want_in, want_out): (Vec<Vec<usize>>, Vec<Vec<usize>>) = match e.op.as_str() {
        "gemm_nn" | "gemm_tn" | "gemm_nt" => {
            anyhow::ensure!(e.dims.len() == 3, "{}: gemm dims are m,n,k", e.name);
            let (m, n, k) = (e.dims[0], e.dims[1], e.dims[2]);
            let a = if e.op == "gemm_tn" { vec![k, m] } else { vec![m, k] };
            let b = if e.op == "gemm_nt" { vec![n, k] } else { vec![k, n] };
            (vec![vec![m, n], a, b], vec![vec![m, n]])
        }
        "gram_matvec" => {
            anyhow::ensure!(e.dims.len() == 3, "{}: gram dims are pm,pk,pc", e.name);
            let (pm, pk, pc) = (e.dims[0], e.dims[1], e.dims[2]);
            (
                vec![vec![pm, pk], vec![pk, pc], vec![1, 1]],
                vec![vec![pk, pc]],
            )
        }
        "rff_expand" => {
            anyhow::ensure!(e.dims.len() == 3, "{}: rff dims are pm,pk0,pd", e.name);
            let (pm, pk0, pd) = (e.dims[0], e.dims[1], e.dims[2]);
            (
                vec![vec![pm, pk0], vec![pk0, pd], vec![1, pd], vec![1, 1]],
                vec![vec![pm, pd]],
            )
        }
        "cg_update" => {
            anyhow::ensure!(e.dims.len() == 2, "{}: cg dims are pm,pc", e.name);
            let (pm, pc) = (e.dims[0], e.dims[1]);
            (
                vec![
                    vec![pm, pc],
                    vec![pm, pc],
                    vec![pm, pc],
                    vec![pm, pc],
                    vec![1, pc],
                ],
                vec![vec![pm, pc], vec![pm, pc]],
            )
        }
        other => anyhow::bail!(
            "artifact {}: unknown op {other:?} — the PJRT stand-in compiles \
             gemm_{{nn,tn,nt}}, gram_matvec, rff_expand, cg_update",
            e.name
        ),
    };
    anyhow::ensure!(
        e.in_shapes == want_in,
        "artifact {}: input shapes {:?} inconsistent with op/dims (want {:?})",
        e.name,
        e.in_shapes,
        want_in
    );
    anyhow::ensure!(
        e.out_shapes == want_out,
        "artifact {}: output shapes {:?} inconsistent with op/dims (want {:?})",
        e.name,
        e.out_shapes,
        want_out
    );
    Ok(())
}

impl LoadedExecutable {
    /// Execute on flat row-major inputs (already shape-checked by
    /// `Runtime::run` against the manifest; lengths are re-checked here
    /// so the kernels below can index safely).
    pub fn execute(&self, inputs: &[&[f64]]) -> crate::Result<Vec<Tensor>> {
        let e = &self.entry;
        anyhow::ensure!(
            inputs.len() == e.in_shapes.len(),
            "artifact {}: want {} inputs, got {}",
            e.name,
            e.in_shapes.len(),
            inputs.len()
        );
        for (i, (data, dims)) in inputs.iter().zip(&e.in_shapes).enumerate() {
            anyhow::ensure!(
                data.len() == dims.iter().product::<usize>(),
                "artifact {} input {i}: data/shape mismatch",
                e.name
            );
        }
        let outs = match e.op.as_str() {
            "gemm_nn" => {
                let (m, n, k) = (e.dims[0], e.dims[1], e.dims[2]);
                let mut c = inputs[0].to_vec();
                gemm_slices(&mut c, m, n, k, inputs[1], k, 1, inputs[2], n, 1, None, None);
                vec![Tensor::new(vec![m, n], c)]
            }
            "gemm_tn" => {
                let (m, n, k) = (e.dims[0], e.dims[1], e.dims[2]);
                let mut c = inputs[0].to_vec();
                // a is stored k×m: logical aᵀ[i,l] strides (1, m)
                gemm_slices(&mut c, m, n, k, inputs[1], 1, m, inputs[2], n, 1, None, None);
                vec![Tensor::new(vec![m, n], c)]
            }
            "gemm_nt" => {
                let (m, n, k) = (e.dims[0], e.dims[1], e.dims[2]);
                let mut c = inputs[0].to_vec();
                // b is stored n×k: logical bᵀ[l,j] strides (1, k)
                gemm_slices(&mut c, m, n, k, inputs[1], k, 1, inputs[2], 1, k, None, None);
                vec![Tensor::new(vec![m, n], c)]
            }
            "gram_matvec" => {
                let (pm, pk, pc) = (e.dims[0], e.dims[1], e.dims[2]);
                let (panel, v, reg) = (inputs[0], inputs[1], inputs[2][0]);
                let mut av = vec![0.0f64; pm * pc];
                gemm_slices(&mut av, pm, pc, pk, panel, pk, 1, v, pc, 1, None, None);
                let mut out: Vec<f64> = v.iter().map(|x| reg * x).collect();
                gemm_slices(&mut out, pk, pc, pm, panel, 1, pk, &av, pc, 1, None, None);
                vec![Tensor::new(vec![pk, pc], out)]
            }
            "rff_expand" => {
                let (pm, pk0, pd) = (e.dims[0], e.dims[1], e.dims[2]);
                let (x, omega, bias, scale) =
                    (inputs[0], inputs[1], inputs[2], inputs[3][0]);
                let mut z = vec![0.0f64; pm * pd];
                gemm_slices(&mut z, pm, pd, pk0, x, pk0, 1, omega, pd, 1, None, None);
                for row in z.chunks_exact_mut(pd) {
                    for (v, b) in row.iter_mut().zip(bias) {
                        *v = scale * (*v + b).cos();
                    }
                }
                vec![Tensor::new(vec![pm, pd], z)]
            }
            "cg_update" => {
                let (pm, pc) = (e.dims[0], e.dims[1]);
                let (x, r, p, q, alpha) =
                    (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
                let mut xo = x.to_vec();
                let mut ro = r.to_vec();
                for i in 0..pm {
                    for j in 0..pc {
                        xo[i * pc + j] += alpha[j] * p[i * pc + j];
                        ro[i * pc + j] -= alpha[j] * q[i * pc + j];
                    }
                }
                vec![Tensor::new(vec![pm, pc], xo), Tensor::new(vec![pm, pc], ro)]
            }
            // unreachable: compile() rejected unknown ops
            other => anyhow::bail!("artifact {}: unknown op {other:?}", e.name),
        };
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(op: &str, dims: Vec<usize>, ins: &str, outs: &str) -> ArtifactEntry {
        let parse = |s: &str| -> Vec<Vec<usize>> {
            s.split(';')
                .map(|sh| sh.split('x').map(|d| d.parse().unwrap()).collect())
                .collect()
        };
        ArtifactEntry {
            name: format!("sim_{op}"),
            op: op.to_string(),
            engine: "xla".to_string(),
            dims,
            in_shapes: parse(ins),
            out_shapes: parse(outs),
            sha: String::new(),
        }
    }

    #[test]
    fn compile_rejects_unknown_op_and_bad_shapes() {
        let c = Client::cpu().unwrap();
        let bad = entry("conv2d", vec![4, 4, 4], "4x4;4x4;4x4", "4x4");
        assert!(c.compile(&bad).is_err());
        // gemm with inconsistent input shape
        let bad = entry("gemm_nn", vec![4, 4, 4], "4x4;4x4;3x4", "4x4");
        assert!(c.compile(&bad).is_err());
        let ok = entry("gemm_nn", vec![4, 4, 4], "4x4;4x4;4x4", "4x4");
        assert!(c.compile(&ok).is_ok());
    }

    #[test]
    fn gemm_variants_match_reference() {
        let c = Client::cpu().unwrap();
        let (m, n, k) = (3usize, 4usize, 2usize);
        let a: Vec<f64> = (0..m * k).map(|i| i as f64 + 1.0).collect(); // m×k
        let b: Vec<f64> = (0..k * n).map(|i| (i as f64) * 0.5 - 1.0).collect(); // k×n
        let seed: Vec<f64> = (0..m * n).map(|i| i as f64 * 0.1).collect();
        let mut want = seed.clone();
        for i in 0..m {
            for j in 0..n {
                for l in 0..k {
                    want[i * n + j] += a[i * k + l] * b[l * n + j];
                }
            }
        }
        // nn
        let exe = c
            .compile(&entry("gemm_nn", vec![m, n, k], "3x4;3x2;2x4", "3x4"))
            .unwrap();
        let out = exe.execute(&[&seed, &a, &b]).unwrap();
        assert_eq!(out[0].data, want);
        // tn: store a transposed (k×m)
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for l in 0..k {
                at[l * m + i] = a[i * k + l];
            }
        }
        let exe = c
            .compile(&entry("gemm_tn", vec![m, n, k], "3x4;2x3;2x4", "3x4"))
            .unwrap();
        let out = exe.execute(&[&seed, &at, &b]).unwrap();
        assert_eq!(out[0].data, want);
        // nt: store b transposed (n×k)
        let mut bt = vec![0.0; n * k];
        for l in 0..k {
            for j in 0..n {
                bt[j * k + l] = b[l * n + j];
            }
        }
        let exe = c
            .compile(&entry("gemm_nt", vec![m, n, k], "3x4;3x2;4x2", "3x4"))
            .unwrap();
        let out = exe.execute(&[&seed, &a, &bt]).unwrap();
        assert_eq!(out[0].data, want);
    }

    #[test]
    fn gram_rff_cg_semantics() {
        let c = Client::cpu().unwrap();
        // gram: pm=2, pk=2, pc=1; panel = [[1,2],[3,4]], v = [1, 1]
        let exe = c
            .compile(&entry("gram_matvec", vec![2, 2, 1], "2x2;2x1;1x1", "2x1"))
            .unwrap();
        let out = exe
            .execute(&[&[1.0, 2.0, 3.0, 4.0], &[1.0, 1.0], &[0.5]])
            .unwrap();
        // panel·v = [3, 7]; panelᵀ·[3,7] = [1·3+3·7, 2·3+4·7] = [24, 34];
        // + 0.5·v = [24.5, 34.5]
        assert_eq!(out[0].data, vec![24.5, 34.5]);

        // rff: pm=1, pk0=1, pd=2; x=[2], Ω=[[0.5, 1.0]], bias=[0, 0.1]
        let exe = c
            .compile(&entry("rff_expand", vec![1, 1, 2], "1x1;1x2;1x2;1x1", "1x2"))
            .unwrap();
        let out = exe.execute(&[&[2.0], &[0.5, 1.0], &[0.0, 0.1], &[3.0]]).unwrap();
        assert!((out[0].data[0] - 3.0 * 1.0f64.cos()).abs() < 1e-15);
        assert!((out[0].data[1] - 3.0 * 2.1f64.cos()).abs() < 1e-15);

        // cg: pm=1, pc=2
        let exe = c
            .compile(&entry(
                "cg_update",
                vec![1, 2],
                "1x2;1x2;1x2;1x2;1x2",
                "1x2;1x2",
            ))
            .unwrap();
        let out = exe
            .execute(&[
                &[1.0, 1.0],
                &[2.0, 2.0],
                &[10.0, 100.0],
                &[1000.0, 10000.0],
                &[0.5, -0.25],
            ])
            .unwrap();
        assert_eq!(out[0].data, vec![6.0, -24.0]);
        assert_eq!(out[1].data, vec![-498.0, 2502.0]);
    }
}
