//! `AlchemistContext` — the session object of the paper's Figure 2 —
//! plus the asynchronous task API of protocol v4: [`AlchemistContext::submit`]
//! returns a [`TaskHandle`] whose `status()` / `wait()` / `cancel()` drive
//! the server-side `Queued → Running → Done | Failed | Cancelled` state
//! machine, and the classic blocking [`AlchemistContext::run_task`] is
//! reimplemented as submit + wait (see `docs/tasks.md`).

use crate::config::Config;
use crate::net::Framed;
use crate::protocol::{ControlMsg, Params, TaskState, DEFAULT_PRIORITY, PROTOCOL_VERSION};
use crate::sparklite::{IndexedRowMatrix, Rdd};

use super::almatrix::AlMatrix;
use super::transfer::{pull_matrix, pull_matrix_cols, push_matrix, TransferStats};

/// Result of a completed task: output matrix proxies plus scalar results
/// and server-side timings (the paper's per-column experiment timings
/// come straight from here).
#[derive(Debug)]
pub struct TaskResult {
    pub outputs: Vec<AlMatrix>,
    pub scalars: Params,
    pub timings: Vec<(String, f64)>,
}

impl TaskResult {
    pub fn output(&self, name: &str) -> crate::Result<&AlMatrix> {
        self.outputs
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow::anyhow!("task produced no output named {name:?}"))
    }

    pub fn timing(&self, name: &str) -> f64 {
        self.timings
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }
}

/// A connected client session (the ACI object). One control socket to the
/// driver; data sockets are opened per transfer by executor threads.
///
/// Each session holds an exclusive worker *group*: `connect` requests the
/// server's default group, [`AlchemistContext::connect_with_workers`]
/// negotiates a size (the paper's `requestWorkers`), and
/// `granted_workers` surfaces what the scheduler actually granted.
pub struct AlchemistContext {
    control: Framed<std::net::TcpStream, std::net::TcpStream>,
    pub session_id: u64,
    /// Data addresses of this session's worker group, index = the
    /// session's group-local worker rank.
    pub worker_addrs: Vec<String>,
    /// Worker-group size the server granted this session.
    pub granted_workers: usize,
    /// Reconnect credential from the handshake ack (protocol v10; 0 =
    /// none issued). While the server's `scheduler.session_linger_s`
    /// window is open after a disconnect, [`AlchemistContext::reconnect`]
    /// presents this token to resume the session — task table, retained
    /// results, and matrix handles intact (`docs/recovery.md`).
    session_token: u64,
    cfg: Config,
    /// Executor threads used for matrix transfer (the paper's "number of
    /// Spark processes"; Table 3 sweeps this).
    pub executors: usize,
}

impl AlchemistContext {
    /// Connect to a running server, accepting the server's default
    /// worker-group size.
    pub fn connect(addr: &str, cfg: &Config, executors: usize) -> crate::Result<Self> {
        Self::connect_with_workers(addr, cfg, executors, 0)
    }

    /// Connect requesting a worker group of `request_workers` ranks
    /// (0 = server default policy). Blocks while the request queues
    /// behind other sessions, up to the server's scheduler timeout.
    pub fn connect_with_workers(
        addr: &str,
        cfg: &Config,
        executors: usize,
        request_workers: usize,
    ) -> crate::Result<Self> {
        Self::connect_with_priority(addr, cfg, executors, request_workers, DEFAULT_PRIORITY)
    }

    /// [`connect_with_workers`](Self::connect_with_workers) at an explicit
    /// admission priority class (protocol v9): 0 = batch, 1 = normal,
    /// 2 = interactive, 3 = urgent. The server clamps the request to its
    /// `scheduler.max_priority` policy; higher classes are granted workers
    /// first, and long-waiting lower classes age upward so nothing
    /// starves (see `docs/scheduler.md`).
    pub fn connect_with_priority(
        addr: &str,
        cfg: &Config,
        executors: usize,
        request_workers: usize,
        priority: u32,
    ) -> crate::Result<Self> {
        Self::connect_named(
            addr,
            cfg,
            executors,
            request_workers,
            priority,
            "alchemist-client",
        )
    }

    /// The full-options constructor (protocol v9): an explicit priority
    /// class plus the client name the session handshakes with. The name
    /// is the scheduler's fair-share *tenant key* — sessions sharing a
    /// name share one `scheduler.weights` bucket, so an application that
    /// opens many sessions should pick one stable name per tenant.
    pub fn connect_named(
        addr: &str,
        cfg: &Config,
        executors: usize,
        request_workers: usize,
        priority: u32,
        client_name: &str,
    ) -> crate::Result<Self> {
        let mut control = Framed::connect(addr, cfg.transfer.buf_bytes)?;
        // request only the transfer knobs that differ from the compiled
        // defaults (0 = "server decides"); the server clamps explicit
        // requests to its limits and echoes the effective values. A
        // default-configured client thus emits the v2 wire shape, so
        // even a strict pre-v3 server can read the frame and answer
        // with its version-mismatch diagnostic instead of dropping the
        // connection on trailing bytes.
        let compiled = Config::default().transfer;
        let req_rows_per_frame = if cfg.transfer.rows_per_frame == compiled.rows_per_frame {
            0
        } else {
            cfg.transfer.rows_per_frame as u32
        };
        let req_buf_bytes = if cfg.transfer.buf_bytes == compiled.buf_bytes {
            0
        } else {
            cfg.transfer.buf_bytes as u64
        };
        let reply = match control.call(&ControlMsg::Handshake {
            client_name: client_name.into(),
            version: PROTOCOL_VERSION,
            request_workers: request_workers as u32,
            rows_per_frame: req_rows_per_frame,
            buf_bytes: req_buf_bytes,
            priority,
        }) {
            Ok(reply) => reply,
            Err(err)
                if (req_rows_per_frame != 0
                    || req_buf_bytes != 0
                    || priority != DEFAULT_PRIORITY)
                    && err.downcast_ref::<std::io::Error>().is_some() =>
            {
                // explicit transfer requests emit the long handshake
                // form, which a STRICT pre-v3 server rejects as trailing
                // bytes and answers with a silent disconnect — the
                // documented elision asymmetry. Probe once with the
                // fields elided (the v2-compatible short form) purely to
                // extract the server's version diagnostic. Gated on an
                // I/O-level failure (EOF/reset): a server that *replied*
                // — even with a version-mismatch Error — already gave
                // its diagnostic, and the probe would just repeat it
                // with a misleading "needs v3+" hint attached.
                return Err(diagnose_handshake_failure(
                    addr,
                    cfg,
                    request_workers as u32,
                    err,
                ));
            }
            Err(err) => return Err(err),
        };
        let mut cfg = cfg.clone();
        let (session_id, granted_workers, worker_addrs, session_token) = match reply {
            ControlMsg::HandshakeAck {
                session_id,
                version,
                granted_workers,
                worker_addrs,
                rows_per_frame,
                buf_bytes,
                session_token,
            } => {
                anyhow::ensure!(version == PROTOCOL_VERSION, "protocol mismatch");
                anyhow::ensure!(
                    granted_workers as usize == worker_addrs.len(),
                    "server granted {granted_workers} workers but sent {} addresses",
                    worker_addrs.len()
                );
                // adopt the negotiated values for every data link this
                // session opens (0 = pre-v3 server: keep local config),
                // re-clamped through the client's OWN limits — a buggy
                // or hostile server's echo must not pick our buffer
                // size (a huge value would make every data link try to
                // allocate it; negotiate also saturates the u64→usize
                // conversion that would wrap on 32-bit targets)
                cfg.transfer = cfg.transfer.negotiate(rows_per_frame, buf_bytes);
                (session_id, granted_workers as usize, worker_addrs, session_token)
            }
            other => anyhow::bail!("bad handshake reply: {other:?}"),
        };
        Ok(AlchemistContext {
            control,
            session_id,
            worker_addrs,
            granted_workers,
            session_token,
            cfg,
            executors: executors.max(1),
        })
    }

    /// The session's reconnect token (protocol v10; 0 when the server
    /// issued none). Record it before a risky stretch: it is the only
    /// credential [`AlchemistContext::reconnect`] accepts.
    pub fn session_token(&self) -> u64 {
        self.session_token
    }

    /// Resume a session whose connection dropped (protocol v10): present
    /// the token from [`AlchemistContext::session_token`] within the
    /// server's `scheduler.session_linger_s` window. Tasks kept running
    /// (and finishing) while disconnected; the returned id list is every
    /// task the session still retains, so the caller can `wait` on the
    /// ones it submitted before the drop and collect their results
    /// (`docs/recovery.md`).
    pub fn reconnect(
        addr: &str,
        cfg: &Config,
        executors: usize,
        token: u64,
    ) -> crate::Result<(Self, Vec<u64>)> {
        anyhow::ensure!(token != 0, "no session token to reattach with");
        let mut control = Framed::connect(addr, cfg.transfer.buf_bytes)?;
        let reply = control.call(&ControlMsg::Reattach { token })?;
        let mut cfg = cfg.clone();
        match reply {
            ControlMsg::ReattachAck {
                session_id,
                granted_workers,
                worker_addrs,
                rows_per_frame,
                buf_bytes,
                task_ids,
            } => {
                anyhow::ensure!(
                    granted_workers as usize == worker_addrs.len(),
                    "server granted {granted_workers} workers but sent {} addresses",
                    worker_addrs.len()
                );
                // same re-clamp as the handshake path: the echoed values
                // must pass through the client's own limits
                cfg.transfer = cfg.transfer.negotiate(rows_per_frame, buf_bytes);
                Ok((
                    AlchemistContext {
                        control,
                        session_id,
                        worker_addrs,
                        granted_workers: granted_workers as usize,
                        session_token: token,
                        cfg,
                        executors: executors.max(1),
                    },
                    task_ids,
                ))
            }
            ControlMsg::Error { message } => {
                anyhow::bail!("reattach rejected: {message}")
            }
            other => anyhow::bail!("bad reattach reply: {other:?}"),
        }
    }

    /// The session's effective transfer configuration (requested knobs
    /// after server-side clamping).
    pub fn transfer_config(&self) -> &crate::config::TransferConfig {
        &self.cfg.transfer
    }

    pub fn num_workers(&self) -> usize {
        self.worker_addrs.len()
    }

    /// `registerLibrary(name, path)` — paper Figure 2.
    pub fn register_library(&mut self, name: &str, path: &str) -> crate::Result<()> {
        match self.control.call(&ControlMsg::RegisterLibrary {
            name: name.into(),
            path: path.into(),
        })? {
            ControlMsg::LibraryRegistered { .. } => Ok(()),
            other => anyhow::bail!("bad reply: {other:?}"),
        }
    }

    /// Ship an `IndexedRowMatrix` to the server: `AlMatrix(A)` in the
    /// paper's API. Returns the proxy plus measured transfer stats.
    pub fn send_matrix(
        &mut self,
        name: &str,
        m: &IndexedRowMatrix,
    ) -> crate::Result<(AlMatrix, TransferStats)> {
        let reply = self.control.call(&ControlMsg::CreateMatrix {
            name: name.into(),
            rows: m.rows as u64,
            cols: m.cols as u64,
        })?;
        let (id, ranges) = match reply {
            ControlMsg::MatrixCreated { id, row_ranges } => (
                id,
                row_ranges
                    .iter()
                    .map(|&(a, b)| (a as usize, b as usize))
                    .collect::<Vec<_>>(),
            ),
            other => anyhow::bail!("bad reply: {other:?}"),
        };
        let al = AlMatrix {
            id,
            rows: m.rows,
            cols: m.cols,
            name: name.into(),
            row_ranges: ranges,
        };
        let stats = push_matrix(
            &al,
            m.rdd.partitions(),
            &self.worker_addrs,
            &self.cfg.transfer,
            self.session_id,
            self.executors,
        )?;
        match self.control.call(&ControlMsg::SealMatrix { id })? {
            ControlMsg::MatrixSealed { rows_received, .. } => {
                anyhow::ensure!(
                    rows_received == m.rows as u64,
                    "server received {rows_received} of {} rows",
                    m.rows
                );
            }
            other => anyhow::bail!("bad reply: {other:?}"),
        }
        Ok((al, stats))
    }

    /// Direct file ingest (protocol v7): ask the server to load an
    /// `hdf5sim` file from ITS OWN filesystem — each worker maps its row
    /// shard and serves it straight out of the page cache. Unlike
    /// [`send_matrix`](Self::send_matrix), zero payload bytes cross the
    /// client connection (the returned stats record `bytes: 0`); the
    /// round-trip is one control message. The server validates the file
    /// before registering anything, so an error means no matrix exists.
    pub fn load_matrix(
        &mut self,
        name: &str,
        path: &str,
    ) -> crate::Result<(AlMatrix, TransferStats)> {
        let t0 = std::time::Instant::now();
        let reply = self.control.call(&ControlMsg::LoadMatrix {
            name: name.into(),
            path: path.into(),
        })?;
        let (info, ranges) = match reply {
            ControlMsg::LoadDone { info, row_ranges } => (
                info,
                row_ranges
                    .iter()
                    .map(|&(a, b)| (a as usize, b as usize))
                    .collect::<Vec<_>>(),
            ),
            other => anyhow::bail!("bad reply: {other:?}"),
        };
        let al = AlMatrix {
            id: info.id,
            rows: info.rows as usize,
            cols: info.cols as usize,
            name: info.name,
            row_ranges: ranges,
        };
        // bytes stays 0: the whole point of direct ingest is that the
        // payload never transits the client link
        let stats = TransferStats {
            bytes: 0,
            secs: t0.elapsed().as_secs_f64(),
            frames: 0,
            executors: 0,
        };
        Ok((al, stats))
    }

    /// Submit `lib.routine(params)` to the session's task queue and
    /// return a [`TaskHandle`] immediately (protocol v4). The handle
    /// borrows this context exclusively — the single control socket is
    /// the session, so all task operations flow through it.
    pub fn submit(
        &mut self,
        lib: &str,
        routine: &str,
        params: Params,
    ) -> crate::Result<TaskHandle<'_>> {
        let reply = self.control.call(&ControlMsg::SubmitTask {
            lib: lib.into(),
            routine: routine.into(),
            params,
        })?;
        match reply {
            ControlMsg::TaskSubmitted { task_id } => {
                Ok(TaskHandle { ctx: self, task_id })
            }
            other => anyhow::bail!("bad reply: {other:?}"),
        }
    }

    /// Re-attach a [`TaskHandle`] to a previously submitted task (handles
    /// borrow the context, so juggling several in-flight tasks means
    /// keeping their ids and re-attaching as needed).
    pub fn task(&mut self, task_id: u64) -> TaskHandle<'_> {
        TaskHandle { ctx: self, task_id }
    }

    /// Invoke `lib.routine(params)` on the server's worker group and
    /// block until it completes — sugar over [`AlchemistContext::submit`]
    /// + [`TaskHandle::wait`], so the v1–v3 synchronous call style keeps
    /// working for every existing caller.
    pub fn run_task(
        &mut self,
        lib: &str,
        routine: &str,
        params: Params,
    ) -> crate::Result<TaskResult> {
        self.submit(lib, routine, params)?.wait()
    }

    /// One task-lifecycle round-trip, unwrapping the status reply.
    fn task_call(&mut self, msg: &ControlMsg) -> crate::Result<TaskState> {
        match self.control.call(msg)? {
            ControlMsg::TaskStatusReply { state, .. } => Ok(state),
            other => anyhow::bail!("bad reply: {other:?}"),
        }
    }

    /// Materialize a `Done` payload into client-side proxies.
    fn resolve_done(
        &mut self,
        outputs: Vec<crate::protocol::MatrixInfo>,
        scalars: Params,
        timings: Vec<(String, f64)>,
    ) -> crate::Result<TaskResult> {
        let mut proxies = Vec::with_capacity(outputs.len());
        for info in outputs {
            // fetch layout for the proxy (one metadata round-trip)
            let ranges = match self
                .control
                .call(&ControlMsg::FetchMatrix { id: info.id })?
            {
                ControlMsg::FetchReady { row_ranges, worker_addrs, .. } => {
                    // v10: the server sends the group's CURRENT data
                    // addresses with every fetch — adopt them, so a rank
                    // replaced from the spare pool mid-session is where
                    // the row reads go, not the dead process
                    if !worker_addrs.is_empty() {
                        self.worker_addrs = worker_addrs;
                    }
                    row_ranges
                        .iter()
                        .map(|&(a, b)| (a as usize, b as usize))
                        .collect::<Vec<_>>()
                }
                other => anyhow::bail!("bad reply: {other:?}"),
            };
            proxies.push(AlMatrix {
                id: info.id,
                rows: info.rows as usize,
                cols: info.cols as usize,
                name: info.name,
                row_ranges: ranges,
            });
        }
        Ok(TaskResult { outputs: proxies, scalars, timings })
    }

    /// Materialize a server matrix on the client —
    /// `alQ.toIndexedRowMatrix()` in the paper's API.
    pub fn to_indexed_row_matrix(
        &mut self,
        m: &AlMatrix,
        num_partitions: usize,
    ) -> crate::Result<(IndexedRowMatrix, TransferStats)> {
        let (mut rows, stats) = pull_matrix(
            m,
            &self.worker_addrs,
            &self.cfg.transfer,
            self.session_id,
            self.executors,
        )?;
        rows.sort_by_key(|r| r.index);
        let irm = IndexedRowMatrix {
            rdd: Rdd::parallelize(rows, num_partitions.max(1)),
            rows: m.rows,
            cols: m.cols,
        };
        Ok((irm, stats))
    }

    /// [`to_indexed_row_matrix`](Self::to_indexed_row_matrix) restricted
    /// to the column window `[start_col, start_col + ncols)` (protocol
    /// v7): only the selected columns' bytes cross the wire, and the
    /// returned matrix is `rows × ncols`.
    pub fn to_indexed_row_matrix_cols(
        &mut self,
        m: &AlMatrix,
        num_partitions: usize,
        start_col: usize,
        ncols: usize,
    ) -> crate::Result<(IndexedRowMatrix, TransferStats)> {
        anyhow::ensure!(
            ncols > 0 && start_col + ncols <= m.cols,
            "column range [{start_col}, {}) out of bounds for {} cols",
            start_col + ncols,
            m.cols
        );
        let (mut rows, stats) = pull_matrix_cols(
            m,
            &self.worker_addrs,
            &self.cfg.transfer,
            self.session_id,
            self.executors,
            start_col,
            ncols,
        )?;
        rows.sort_by_key(|r| r.index);
        let irm = IndexedRowMatrix {
            rdd: Rdd::parallelize(rows, num_partitions.max(1)),
            rows: m.rows,
            cols: ncols,
        };
        Ok((irm, stats))
    }

    /// Drop a server-side matrix.
    pub fn free(&mut self, m: &AlMatrix) -> crate::Result<()> {
        match self.control.call(&ControlMsg::FreeMatrix { id: m.id })? {
            ControlMsg::Freed { .. } => Ok(()),
            other => anyhow::bail!("bad reply: {other:?}"),
        }
    }

    /// List live server-side matrices.
    pub fn list_matrices(&mut self) -> crate::Result<Vec<(u64, String, usize, usize)>> {
        match self.control.call(&ControlMsg::ListMatrices)? {
            ControlMsg::MatrixList { infos } => Ok(infos
                .into_iter()
                .map(|i| (i.id, i.name, i.rows as usize, i.cols as usize))
                .collect()),
            other => anyhow::bail!("bad reply: {other:?}"),
        }
    }

    /// End the session (`ac.stop()`); the server keeps running.
    pub fn stop(self) {
        // dropping the socket ends the session server-side
    }

    /// Ask the server to shut down entirely.
    pub fn shutdown_server(mut self) -> crate::Result<()> {
        match self.control.call(&ControlMsg::Shutdown)? {
            ControlMsg::Bye => Ok(()),
            other => anyhow::bail!("bad reply: {other:?}"),
        }
    }

    /// Open a push-based scheduler metrics stream (protocol v9). This is
    /// a dedicated connection — `SubscribeMetrics` must be the first
    /// message on it and it never becomes a session, so the stream is an
    /// associated function rather than a session method; it neither holds
    /// workers nor counts against `scheduler.max_sessions`.
    /// `interval_ms = 0` accepts the server's configured cadence
    /// (`scheduler.metrics_interval_ms`). Iterate the returned stream for
    /// one [`MetricsUpdate`] per interval; drop it to unsubscribe.
    pub fn subscribe_metrics(
        addr: &str,
        cfg: &Config,
        interval_ms: u64,
    ) -> crate::Result<MetricsStream> {
        let mut control = Framed::connect(addr, cfg.transfer.buf_bytes)?;
        control.send_ctrl(&ControlMsg::SubscribeMetrics { interval_ms })?;
        Ok(MetricsStream { control })
    }
}

/// One pushed scheduler snapshot: a monotonic sequence number plus the
/// snapshot as a single JSON line (the wire format of `SchedSnapshot`,
/// see `docs/scheduler.md` for the schema). Kept as a string so the
/// client needs no JSON dependency — append it to a `.jsonl` log or hand
/// it to any external parser.
#[derive(Debug, Clone)]
pub struct MetricsUpdate {
    pub seq: u64,
    pub json: String,
}

/// An open metrics subscription (see
/// [`AlchemistContext::subscribe_metrics`]). Iterating blocks until the
/// next push lands; the iterator ends (`None`) when the server shuts
/// down. Dropping the stream closes the connection, which unsubscribes.
pub struct MetricsStream {
    control: Framed<std::net::TcpStream, std::net::TcpStream>,
}

impl Iterator for MetricsStream {
    type Item = crate::Result<MetricsUpdate>;

    fn next(&mut self) -> Option<Self::Item> {
        match self.control.recv_ctrl() {
            Ok(ControlMsg::MetricsSnapshot { seq, json }) => {
                Some(Ok(MetricsUpdate { seq, json }))
            }
            Ok(ControlMsg::Error { message }) => {
                Some(Err(anyhow::anyhow!("metrics stream error: {message}")))
            }
            Ok(other) => Some(Err(anyhow::anyhow!(
                "bad metrics stream frame: {other:?}"
            ))),
            // EOF/reset = server went away: end of stream, not an error
            Err(_) => None,
        }
    }
}

/// Turn an opaque long-form handshake failure into the server's version
/// diagnostic when possible: reconnect and send the short (v2-compatible)
/// handshake form, which even a strict pre-v3 server can decode and
/// answer. If that probe surfaces a version mismatch, report it (with the
/// original failure attached); otherwise the original error stands —
/// the server is current and the failure was something else.
fn diagnose_handshake_failure(
    addr: &str,
    cfg: &Config,
    request_workers: u32,
    original: anyhow::Error,
) -> anyhow::Error {
    let probe = (|| -> crate::Result<ControlMsg> {
        let mut control = Framed::connect(addr, cfg.transfer.buf_bytes)?;
        control.send_ctrl(&ControlMsg::Handshake {
            client_name: "alchemist-client".into(),
            version: PROTOCOL_VERSION,
            request_workers,
            rows_per_frame: 0,
            buf_bytes: 0,
            priority: DEFAULT_PRIORITY,
        })?;
        control.recv_ctrl()
    })();
    match probe {
        Ok(ControlMsg::Error { message }) if message.contains("version mismatch") => {
            original.context(format!(
                "server rejected the long handshake form carrying explicit \
                 transfer settings; it answered a short probe with: {message} \
                 (explicit rows_per_frame/buf_bytes/priority requests \
                 require a v3+ server)"
            ))
        }
        _ => original,
    }
}

/// One server-side wait slice per [`TaskHandle::wait`] round-trip: long
/// enough that a typical task completes inside a single blocking call,
/// short enough that a wedged rank cannot pin the control thread forever.
const WAIT_SLICE_MS: u64 = 10_000;

/// A submitted task (protocol v4). Holds the context mutably — the
/// session's single control socket serializes all task operations.
pub struct TaskHandle<'a> {
    ctx: &'a mut AlchemistContext,
    pub task_id: u64,
}

impl TaskHandle<'_> {
    /// Poll the task's state without blocking (running tasks carry
    /// cross-rank aggregated progress: min iteration, worst residual).
    pub fn status(&mut self) -> crate::Result<TaskState> {
        self.ctx
            .task_call(&ControlMsg::TaskStatus { task_id: self.task_id })
    }

    /// Request cooperative cancellation. A queued task is `Cancelled`
    /// immediately; a running task stays `Running` until its ranks
    /// observe the token (within one iteration for the iterative
    /// routines) — follow with [`TaskHandle::wait`] to see it land.
    pub fn cancel(&mut self) -> crate::Result<TaskState> {
        self.ctx.task_call(&ControlMsg::CancelTask {
            task_id: self.task_id,
            hard_after_ms: 0,
        })
    }

    /// [`TaskHandle::cancel`] with an escalation deadline (protocol v5):
    /// if the task is still running `hard_after_ms` after the cooperative
    /// request, the server poisons the group's communicator and the
    /// routine is forcibly unwound at its next collective — so even a
    /// routine that ignores the cooperative contract ends within the
    /// deadline plus one collective, instead of its remaining runtime.
    pub fn cancel_hard(&mut self, hard_after_ms: u64) -> crate::Result<TaskState> {
        self.ctx.task_call(&ControlMsg::CancelTask {
            task_id: self.task_id,
            hard_after_ms,
        })
    }

    /// Block server-side until the task is terminal or `timeout_ms`
    /// elapses; returns the state either way (a non-terminal state means
    /// the timeout fired first).
    pub fn wait_timeout(&mut self, timeout_ms: u64) -> crate::Result<TaskState> {
        self.ctx.task_call(&ControlMsg::WaitTask {
            task_id: self.task_id,
            timeout_ms,
        })
    }

    /// Block until the task completes; `Done` materializes into a
    /// [`TaskResult`], `Failed` and `Cancelled` surface as errors (the
    /// failure message carries the per-rank breakdown).
    pub fn wait(self) -> crate::Result<TaskResult> {
        let TaskHandle { ctx, task_id } = self;
        loop {
            let state = ctx.task_call(&ControlMsg::WaitTask {
                task_id,
                timeout_ms: WAIT_SLICE_MS,
            })?;
            match state {
                TaskState::Done { outputs, scalars, timings } => {
                    return ctx.resolve_done(outputs, scalars, timings);
                }
                TaskState::Failed { message, .. } => {
                    anyhow::bail!("task {task_id} failed: {message}");
                }
                TaskState::Cancelled => {
                    anyhow::bail!("task {task_id} was cancelled");
                }
                // Queued / Running: the wait slice expired, go around
                _ => {}
            }
        }
    }
}
