//! Collective algorithms over point-to-point send/recv.
//!
//! These are the textbook implementations the MPI runtimes the paper
//! depends on would use at this scale: binomial trees for
//! broadcast/reduce, recursive doubling or a bandwidth-optimal ring for
//! allreduce (selected from the group shape and vector length, exactly
//! like an MPI tuned-collectives table — see
//! [`ALLREDUCE_DOUBLING_MAX_ELEMS`]), linear gather/scatter rooted at
//! rank 0 (the Alchemist driver-adjacent rank).
//!
//! Every algorithm debug-asserts the [`TAG_WINDOW`] contract on entry:
//! the caller's `base_tag` must be window-aligned and every offset the
//! algorithm derives must stay inside the window, so two concurrent
//! collectives can never interleave their messages.
//!
//! Every algorithm is `Result`-returning and propagates the first
//! [`CommError`] it observes (protocol v5 fault isolation): when a peer
//! rank fails and poisons the group, a rank blocked mid-algorithm wakes
//! from its `recv` with the error and unwinds instead of waiting forever.
//! The sends a failing algorithm already queued are dropped by the
//! driver's fabric reset between tasks. Callers whose groups can never be
//! poisoned (single-rank groups, direct library use, benches) may use the
//! [`infallible`] wrappers.

use crate::util::even_ranges;

use super::{CommError, Communicator, TAG_WINDOW};

/// Above this element count the ring allreduce's bandwidth optimality
/// (2·(p−1)/p·n elements per rank) wins over recursive doubling's lower
/// latency (log₂ p rounds); at or below it — and only on power-of-two
/// group sizes, where the doubling pattern is exact — [`allreduce_sum`]
/// switches to recursive doubling. Deliberately a compile-time constant
/// rather than a config knob: every rank must derive the *same* algorithm
/// from the shape alone, and ranks in different OS processes (protocol
/// v8 network fabric) do not share a runtime config.
pub const ALLREDUCE_DOUBLING_MAX_ELEMS: usize = 4096;

/// Debug-time guard for the tag-space contract: `base_tag` must be
/// [`TAG_WINDOW`]-aligned and `max_offset` (the largest offset this
/// invocation can add) must stay inside the window. Violations are
/// programming errors — two collectives sharing a window would silently
/// interleave messages — so they assert instead of returning an error.
#[inline]
fn check_tags(base_tag: u64, max_offset: u64) {
    debug_assert_eq!(
        base_tag % TAG_WINDOW,
        0,
        "collective base tag {base_tag:#x} is not TAG_WINDOW-aligned"
    );
    debug_assert!(
        max_offset < TAG_WINDOW,
        "collective tag offsets (max {max_offset}) overflow TAG_WINDOW"
    );
}

/// Entry check every algorithm performs before moving any data: a
/// poisoned group must fail even on paths that would otherwise touch no
/// mailbox at all (size-1 groups, send-only legs) — a hard cancel on a
/// single-worker session still has to unwind the routine at its next
/// collective, exactly like on a multi-rank group. One atomic load in
/// the unpoisoned steady state.
fn entry_check(comm: &dyn Communicator) -> Result<(), CommError> {
    match comm.poison_cause() {
        Some(cause) => Err(cause.to_err()),
        None => Ok(()),
    }
}

/// Binomial-tree broadcast from `root`. Every rank passes the same `buf`
/// in; on return all ranks hold root's data.
pub fn broadcast(
    comm: &dyn Communicator,
    base_tag: u64,
    root: usize,
    buf: &mut Vec<f64>,
) -> Result<(), CommError> {
    entry_check(comm)?;
    check_tags(base_tag, 0);
    let size = comm.size();
    if size == 1 {
        return Ok(());
    }
    // Relative rank so any root works with the rank-0 tree.
    let vrank = (comm.rank() + size - root) % size;
    let mut mask = 1usize;
    // receive phase: find the bit where our parent contacted us
    while mask < size {
        if vrank & mask != 0 {
            let parent = (vrank - mask + root) % size;
            *buf = comm.recv(parent, base_tag)?;
            break;
        }
        mask <<= 1;
    }
    // send phase: forward to children below our lowest set bit
    let mut child_mask = if vrank == 0 {
        // root starts at the highest power of two < size
        let mut m = 1usize;
        while m < size {
            m <<= 1;
        }
        m >> 1
    } else {
        mask >> 1
    };
    while child_mask > 0 {
        let vchild = vrank | child_mask;
        if vchild < size && vchild != vrank {
            let child = (vchild + root) % size;
            comm.send(child, base_tag, buf.clone());
        }
        child_mask >>= 1;
    }
    Ok(())
}

/// Binomial-tree sum-reduce to `root`; on root, `buf` holds the elementwise
/// sum over all ranks; other ranks' buffers are consumed (contents
/// unspecified after the call).
pub fn reduce_sum(
    comm: &dyn Communicator,
    base_tag: u64,
    root: usize,
    buf: &mut Vec<f64>,
) -> Result<(), CommError> {
    entry_check(comm)?;
    let size = comm.size();
    // Offsets are the binomial masks, all < size.
    check_tags(base_tag, size as u64 - 1);
    if size == 1 {
        return Ok(());
    }
    let vrank = (comm.rank() + size - root) % size;
    let mut mask = 1usize;
    while mask < size {
        if vrank & mask != 0 {
            // send to parent and exit
            let parent = (vrank - mask + root) % size;
            comm.send(parent, base_tag + mask as u64, std::mem::take(buf));
            return Ok(());
        }
        // receive from child (if it exists) and accumulate
        let vchild = vrank | mask;
        if vchild < size {
            let child = (vchild + root) % size;
            let other = comm.recv(child, base_tag + mask as u64)?;
            debug_assert_eq!(other.len(), buf.len());
            for (a, b) in buf.iter_mut().zip(&other) {
                *a += b;
            }
        }
        mask <<= 1;
    }
    Ok(())
}

/// Allreduce: all ranks end with the elementwise sum. Topology-aware
/// algorithm selection, decided identically on every rank from the group
/// shape and vector length alone (no negotiation round): short vectors on
/// power-of-two groups take latency-optimal recursive doubling (log₂ p
/// rounds of the full vector), everything else takes the
/// bandwidth-optimal ring (reduce-scatter + allgather, 2·(p−1)/p · n
/// elements over the wire per rank). On error, `buf` is left partially
/// reduced (callers unwind; the driver resets the fabric between tasks).
pub fn allreduce_sum(
    comm: &dyn Communicator,
    base_tag: u64,
    buf: &mut [f64],
) -> Result<(), CommError> {
    entry_check(comm)?;
    let p = comm.size();
    // Worst case is the ring's allgather phase: offsets up to 2(p−1).
    check_tags(base_tag, 2 * (p as u64 - 1));
    if p == 1 {
        return Ok(());
    }
    if p.is_power_of_two() && buf.len() <= ALLREDUCE_DOUBLING_MAX_ELEMS {
        return allreduce_doubling(comm, base_tag, buf);
    }
    allreduce_ring(comm, base_tag, buf)
}

/// Recursive doubling: in round `s`, exchange the full partially-reduced
/// vector with rank `rank ^ 2^s` and accumulate. log₂ p rounds; for
/// short vectors the wire time is dominated by per-message latency and
/// this beats the ring's 2(p−1) serialized steps.
fn allreduce_doubling(
    comm: &dyn Communicator,
    base_tag: u64,
    buf: &mut [f64],
) -> Result<(), CommError> {
    let p = comm.size();
    let rank = comm.rank();
    debug_assert!(p.is_power_of_two());
    let mut dist = 1usize;
    let mut step = 0u64;
    while dist < p {
        let partner = rank ^ dist;
        comm.send(partner, base_tag + step, buf.to_vec());
        let incoming = comm.recv(partner, base_tag + step)?;
        debug_assert_eq!(incoming.len(), buf.len());
        for (a, b) in buf.iter_mut().zip(&incoming) {
            *a += b;
        }
        dist <<= 1;
        step += 1;
    }
    Ok(())
}

/// Ring allreduce (reduce-scatter + allgather), bandwidth-optimal for
/// long vectors.
fn allreduce_ring(
    comm: &dyn Communicator,
    base_tag: u64,
    buf: &mut [f64],
) -> Result<(), CommError> {
    let p = comm.size();
    let rank = comm.rank();
    let chunks = even_ranges(buf.len(), p);
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;

    // Phase 1: reduce-scatter. In step s, send chunk (rank - s) and
    // receive + accumulate chunk (rank - s - 1).
    for s in 0..p - 1 {
        let send_idx = (rank + p - s) % p;
        let recv_idx = (rank + p - s - 1) % p;
        let (a, b) = chunks[send_idx];
        comm.send(next, base_tag + s as u64, buf[a..b].to_vec());
        let incoming = comm.recv(prev, base_tag + s as u64)?;
        let (a, b) = chunks[recv_idx];
        debug_assert_eq!(incoming.len(), b - a);
        for (dst, src) in buf[a..b].iter_mut().zip(&incoming) {
            *dst += src;
        }
    }
    // Phase 2: allgather of the reduced chunks. In step s, send chunk
    // (rank + 1 - s) and receive chunk (rank - s).
    for s in 0..p - 1 {
        let send_idx = (rank + 1 + p - s) % p;
        let recv_idx = (rank + p - s) % p;
        let (a, b) = chunks[send_idx];
        comm.send(next, base_tag + (p + s) as u64, buf[a..b].to_vec());
        let incoming = comm.recv(prev, base_tag + (p + s) as u64)?;
        let (a, b) = chunks[recv_idx];
        buf[a..b].copy_from_slice(&incoming);
    }
    Ok(())
}

/// Gather each rank's (possibly differently-sized) vector to `root`.
/// Returns `Some(parts)` on root (index = rank), `None` elsewhere.
pub fn gather(
    comm: &dyn Communicator,
    base_tag: u64,
    root: usize,
    mine: Vec<f64>,
) -> Result<Option<Vec<Vec<f64>>>, CommError> {
    entry_check(comm)?;
    check_tags(base_tag, comm.size() as u64 - 1);
    if comm.rank() == root {
        let mut parts = vec![Vec::new(); comm.size()];
        for r in 0..comm.size() {
            if r == root {
                parts[r] = mine.clone();
            } else {
                parts[r] = comm.recv(r, base_tag + r as u64)?;
            }
        }
        Ok(Some(parts))
    } else {
        comm.send(root, base_tag + comm.rank() as u64, mine);
        Ok(None)
    }
}

/// Scatter `parts` (index = rank) from `root`; returns this rank's part.
pub fn scatter(
    comm: &dyn Communicator,
    base_tag: u64,
    root: usize,
    parts: Option<Vec<Vec<f64>>>,
) -> Result<Vec<f64>, CommError> {
    entry_check(comm)?;
    check_tags(base_tag, comm.size() as u64 - 1);
    if comm.rank() == root {
        let parts = parts.expect("root must supply parts");
        assert_eq!(parts.len(), comm.size());
        let mut mine = Vec::new();
        for (r, part) in parts.into_iter().enumerate() {
            if r == root {
                mine = part;
            } else {
                comm.send(r, base_tag + r as u64, part);
            }
        }
        Ok(mine)
    } else {
        comm.recv(root, base_tag + comm.rank() as u64)
    }
}

/// Allgather: everyone ends with the concatenation (by rank) of all
/// inputs. Implemented as ring rotation, (p−1) steps.
pub fn allgather(
    comm: &dyn Communicator,
    base_tag: u64,
    mine: Vec<f64>,
) -> Result<Vec<Vec<f64>>, CommError> {
    entry_check(comm)?;
    let p = comm.size();
    // Ring steps s < p−1.
    check_tags(base_tag, (p as u64).saturating_sub(2));
    let rank = comm.rank();
    let mut parts: Vec<Vec<f64>> = vec![Vec::new(); p];
    parts[rank] = mine;
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;
    for s in 0..p - 1 {
        let send_idx = (rank + p - s) % p;
        let recv_idx = (rank + p - s - 1) % p;
        comm.send(next, base_tag + s as u64, parts[send_idx].clone());
        parts[recv_idx] = comm.recv(prev, base_tag + s as u64)?;
    }
    Ok(parts)
}

/// Infallible convenience wrappers for callers whose groups can never be
/// poisoned — single-rank groups, direct library use, tests, and the
/// paper-table benches. The fallible variants' only error source is the
/// coordinator's poison/hard-cancel machinery, so outside it these
/// `expect`s are unreachable; inside the coordinator, use the fallible
/// variants and propagate.
pub mod infallible {
    use super::Communicator;

    const MSG: &str = "collective failed on an unpoisoned group";

    pub fn broadcast(comm: &dyn Communicator, base_tag: u64, root: usize, buf: &mut Vec<f64>) {
        super::broadcast(comm, base_tag, root, buf).expect(MSG);
    }

    pub fn reduce_sum(comm: &dyn Communicator, base_tag: u64, root: usize, buf: &mut Vec<f64>) {
        super::reduce_sum(comm, base_tag, root, buf).expect(MSG);
    }

    pub fn allreduce_sum(comm: &dyn Communicator, base_tag: u64, buf: &mut [f64]) {
        super::allreduce_sum(comm, base_tag, buf).expect(MSG);
    }

    pub fn gather(
        comm: &dyn Communicator,
        base_tag: u64,
        root: usize,
        mine: Vec<f64>,
    ) -> Option<Vec<Vec<f64>>> {
        super::gather(comm, base_tag, root, mine).expect(MSG)
    }

    pub fn scatter(
        comm: &dyn Communicator,
        base_tag: u64,
        root: usize,
        parts: Option<Vec<Vec<f64>>>,
    ) -> Vec<f64> {
        super::scatter(comm, base_tag, root, parts).expect(MSG)
    }

    pub fn allgather(comm: &dyn Communicator, base_tag: u64, mine: Vec<f64>) -> Vec<Vec<f64>> {
        super::allgather(comm, base_tag, mine).expect(MSG)
    }

    pub fn barrier(comm: &dyn Communicator) {
        comm.barrier().expect(MSG);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::LocalComm;

    /// Run `f` on every rank of an n-group and return the per-rank results.
    pub fn run_group<T, F>(n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(&LocalComm) -> T + Send + Sync + Clone + 'static,
    {
        let comms = LocalComm::group(n, None);
        let mut handles = Vec::new();
        for c in comms {
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(&c)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn broadcast_all_roots_all_sizes() {
        for p in 1..=5usize {
            for root in 0..p {
                let out = run_group(p, move |c| {
                    let mut buf = if c.rank() == root {
                        vec![3.5, -1.0, 7.0]
                    } else {
                        Vec::new()
                    };
                    broadcast(c, 10 * TAG_WINDOW, root, &mut buf).unwrap();
                    buf
                });
                for v in out {
                    assert_eq!(v, vec![3.5, -1.0, 7.0], "p={p} root={root}");
                }
            }
        }
    }

    #[test]
    fn reduce_sum_matches_serial() {
        for p in 1..=6usize {
            let out = run_group(p, move |c| {
                let mut buf = vec![c.rank() as f64 + 1.0, 10.0];
                reduce_sum(c, 20 * TAG_WINDOW, 0, &mut buf).unwrap();
                (c.rank(), buf)
            });
            let expect0: f64 = (1..=p).map(|r| r as f64).sum();
            for (rank, buf) in out {
                if rank == 0 {
                    assert_eq!(buf, vec![expect0, 10.0 * p as f64]);
                }
            }
        }
    }

    #[test]
    fn allreduce_matches_serial_various_lengths() {
        for p in 1..=5usize {
            for n in [1usize, 2, 7, 64, 129] {
                let out = run_group(p, move |c| {
                    let mut buf: Vec<f64> =
                        (0..n).map(|i| (i + c.rank() * 100) as f64).collect();
                    allreduce_sum(c, 30 * TAG_WINDOW, &mut buf).unwrap();
                    buf
                });
                let want: Vec<f64> = (0..n)
                    .map(|i| {
                        (0..p).map(|r| (i + r * 100) as f64).sum::<f64>()
                    })
                    .collect();
                for v in out {
                    assert_eq!(v, want, "p={p} n={n}");
                }
            }
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        for p in 1..=4usize {
            let out = run_group(p, move |c| {
                let mine = vec![c.rank() as f64; c.rank() + 1];
                let gathered = gather(c, 40 * TAG_WINDOW, 0, mine).unwrap();
                // root redistributes what it gathered
                scatter(c, 41 * TAG_WINDOW, 0, gathered).unwrap()
            });
            for (r, v) in out.into_iter().enumerate() {
                assert_eq!(v, vec![r as f64; r + 1]);
            }
        }
    }

    #[test]
    fn allgather_concatenates_by_rank() {
        for p in 1..=5usize {
            let out = run_group(p, move |c| {
                allgather(c, 50 * TAG_WINDOW, vec![c.rank() as f64 * 2.0]).unwrap()
            });
            for parts in out {
                assert_eq!(parts.len(), p);
                for (r, part) in parts.iter().enumerate() {
                    assert_eq!(part, &vec![r as f64 * 2.0]);
                }
            }
        }
    }

    #[test]
    fn infallible_wrappers_match_fallible_results() {
        let out = run_group(3, |c| {
            let mut buf = vec![c.rank() as f64; 4];
            infallible::allreduce_sum(c, 60 * TAG_WINDOW, &mut buf);
            infallible::barrier(c);
            buf
        });
        for v in out {
            assert_eq!(v, vec![3.0; 4]);
        }
    }

    #[test]
    fn poisoned_group_fails_every_algorithm_fast() {
        use crate::collectives::{CommError, PoisonCause};
        let comms = LocalComm::group(2, None);
        comms[0].poison(PoisonCause::RankFailed(1));
        let c = &comms[0];
        let mut buf = vec![1.0, 2.0];
        assert_eq!(
            allreduce_sum(c, 70 * TAG_WINDOW, &mut buf).unwrap_err(),
            CommError::PeerFailed { rank: 1 }
        );
        assert!(broadcast(c, 71 * TAG_WINDOW, 1, &mut buf).is_err());
        assert!(c.barrier().is_err());
        // gather on a non-root rank only sends — but root would hang, so
        // the root path must error
        assert!(gather(c, 72 * TAG_WINDOW, 0, vec![0.0]).is_err());

        // size-1 groups must observe the poison too: a hard cancel on a
        // single-worker session has no peers, but its routine's next
        // collective must still unwind it (the early-return path cannot
        // skip the check)
        let solo = LocalComm::group(1, None).pop().unwrap();
        solo.poison(crate::collectives::PoisonCause::HardCancel);
        let mut buf = vec![1.0];
        assert_eq!(
            allreduce_sum(&solo, 73 * TAG_WINDOW, &mut buf).unwrap_err(),
            CommError::Cancelled
        );
        assert!(solo.barrier().is_err());
        assert!(allgather(&solo, 74 * TAG_WINDOW, vec![0.0]).is_err());
    }

    /// The doubling/ring switch must be invisible to callers: identical
    /// sums on both sides of the element threshold, on power-of-two
    /// groups (eligible for doubling) and odd groups (always ring).
    #[test]
    fn allreduce_selects_algorithm_consistently_across_threshold() {
        let sizes = [
            1usize,
            ALLREDUCE_DOUBLING_MAX_ELEMS - 1,
            ALLREDUCE_DOUBLING_MAX_ELEMS,
            ALLREDUCE_DOUBLING_MAX_ELEMS + 1,
        ];
        for p in [2usize, 3, 4] {
            for n in sizes {
                let out = run_group(p, move |c| {
                    let mut buf: Vec<f64> = (0..n)
                        .map(|i| (i % 97) as f64 + c.rank() as f64)
                        .collect();
                    allreduce_sum(c, 30 * TAG_WINDOW, &mut buf).unwrap();
                    buf
                });
                let want: Vec<f64> = (0..n)
                    .map(|i| {
                        (0..p)
                            .map(|r| (i % 97) as f64 + r as f64)
                            .sum::<f64>()
                    })
                    .collect();
                for v in out {
                    assert_eq!(v, want, "p={p} n={n}");
                }
            }
        }
    }

    /// Satellite guard: an unaligned base tag trips the debug assert. A
    /// size-1 group runs the collective on the calling thread, so the
    /// panic surfaces as this test's own (instead of being folded into a
    /// rank thread's join error).
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "TAG_WINDOW")]
    fn unaligned_base_tag_panics_in_debug() {
        let solo = LocalComm::group(1, None).pop().unwrap();
        let mut buf = vec![1.0];
        let _ = allreduce_sum(&solo, 12345, &mut buf);
    }
}
