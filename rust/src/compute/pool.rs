//! Hand-rolled intra-rank threadpool for the native engine (rayon is not
//! in the offline vendor set).
//!
//! One pool lives inside each rank's [`super::NativeEngine`]; the engine
//! splits its hot ops over *fixed, shape-derived* work chunks and runs
//! them through [`ThreadPool::run`]. Two properties matter more than raw
//! scheduling cleverness:
//!
//! * **Caller participation** — the worker-rank thread that calls
//!   [`run`](ThreadPool::run) drains the job queue alongside the pool
//!   threads, so a pool of `threads = n` uses exactly `n` runnable
//!   threads (`n − 1` spawned + the caller), never `n + 1`. With
//!   `threads = 1` no threads are spawned at all and jobs execute inline,
//!   in order — the serial baseline the determinism suite compares
//!   against.
//! * **Deterministic result order** — [`run`](ThreadPool::run) returns
//!   job results *in job-index order* regardless of which thread finished
//!   what first. Callers that reduce (e.g. the Gram partial sums in
//!   `NativeEngine::gram_matvec`) combine the returned vector left to
//!   right, so floating-point results are bit-identical for any thread
//!   count (see `docs/compute.md`, "Determinism contract").
//!
//! The pool intentionally has no futures, no work stealing between pools
//! and no unbounded queue growth: a scope enqueues its jobs, the members
//! race to drain them, and `run` blocks until the last job lands.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased job as it sits in the queue. Lifetime is erased on entry
/// (see the SAFETY note in [`ThreadPool::run`]); the latch in `run`
/// guarantees every job finishes before the borrows it captured expire.
type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    cond: Condvar,
}

/// Completion state of one `run` scope.
struct ScopeState<R> {
    /// One slot per job, filled by whichever thread executes it.
    results: Mutex<Vec<Option<R>>>,
    /// Jobs not yet finished; `run` returns when this hits zero.
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

/// A fixed-size pool of compute threads. `threads` counts the calling
/// thread: `new(4)` spawns 3 workers and `run` makes the caller the 4th.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Build a pool with `threads` total parallelism (0 is treated as 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            cond: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("engine-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn engine pool thread")
            })
            .collect();
        ThreadPool { shared, workers, threads }
    }

    /// Total parallelism (spawned workers + the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute every job, blocking until all have finished, and return
    /// their results **in job-index order**. The caller drains the queue
    /// alongside the pool threads. If any job panics, `run` panics after
    /// all jobs have settled (no job is left half-running against freed
    /// borrows).
    pub fn run<'env, R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        F: FnOnce() -> R + Send + 'env,
        R: Send + 'env,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        // serial fast path: nothing to coordinate with, run inline in
        // order (this is also the `threads = 1` determinism baseline)
        if self.workers.is_empty() || n == 1 {
            return jobs.into_iter().map(|j| j()).collect();
        }
        let state = Arc::new(ScopeState::<R> {
            results: Mutex::new((0..n).map(|_| None).collect()),
            pending: Mutex::new(n),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            for (idx, job) in jobs.into_iter().enumerate() {
                let state = state.clone();
                let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
                        Ok(r) => state.results.lock().unwrap()[idx] = Some(r),
                        Err(_) => state.panicked.store(true, Ordering::SeqCst),
                    }
                    let mut pending = state.pending.lock().unwrap();
                    *pending -= 1;
                    if *pending == 0 {
                        state.done.notify_all();
                    }
                });
                // SAFETY: lifetime erasure only. `run` does not return
                // until `pending` reaches zero, i.e. until every job (and
                // its captured `'env` borrows) has finished executing, so
                // no job can outlive the environment it borrows. The fat
                // pointer layout of `Box<dyn FnOnce() + Send>` does not
                // depend on the erased lifetime.
                let wrapped: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(wrapped)
                };
                q.jobs.push_back(wrapped);
            }
            self.shared.cond.notify_all();
        }
        // caller participates: drain jobs (possibly another scope's, if
        // this pool is ever shared) until the queue is empty, then wait
        // for our own stragglers still running on pool threads
        loop {
            let job = self.shared.queue.lock().unwrap().jobs.pop_front();
            match job {
                Some(j) => j(),
                None => break,
            }
        }
        let mut pending = state.pending.lock().unwrap();
        while *pending > 0 {
            pending = state.done.wait(pending).unwrap();
        }
        drop(pending);
        if state.panicked.load(Ordering::SeqCst) {
            // drop the completed jobs' results NOW, on this thread, while
            // `'env` is still alive: a pool worker may release the last
            // ScopeState Arc after this frame has unwound, and an `R`
            // whose Drop touches `'env`-borrowed data would then run
            // against a dead stack frame
            state.results.lock().unwrap().clear();
            panic!("engine pool job panicked");
        }
        let mut results = state.results.lock().unwrap();
        results
            .drain(..)
            .map(|r| r.expect("pool job finished without storing a result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("threads", &self.threads).finish()
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.shutdown {
                    return;
                }
                q = shared.cond.wait(q).unwrap();
            }
        };
        // wrapped jobs catch their own panics; this is a backstop so a
        // hypothetical raw panic can never kill a pool thread silently
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_job_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..64)
            .map(|i| {
                move || {
                    // stagger so completion order differs from job order
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    i * 2
                }
            })
            .collect();
        let got = pool.run(jobs);
        assert_eq!(got, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_may_borrow_the_callers_stack() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u64; 40];
        {
            let jobs: Vec<_> = data
                .chunks_mut(10)
                .enumerate()
                .map(|(c, chunk)| {
                    move || {
                        for (i, x) in chunk.iter_mut().enumerate() {
                            *x = (c * 10 + i) as u64;
                        }
                    }
                })
                .collect();
            pool.run(jobs);
        }
        assert_eq!(data, (0..40).collect::<Vec<u64>>());
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let caller = std::thread::current().id();
        let got = pool.run(vec![
            move || std::thread::current().id() == caller,
            move || std::thread::current().id() == caller,
        ]);
        assert_eq!(got, vec![true, true]);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.run(vec![|| 7]), vec![7]);
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
            Box::new(|| 3),
        ];
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run(jobs)));
        assert!(err.is_err());
        // the pool is still usable after a scope panicked
        assert_eq!(pool.run(vec![|| 5, || 6]), vec![5, 6]);
    }

    #[test]
    fn many_more_jobs_than_threads() {
        let pool = ThreadPool::new(2);
        let got = pool.run((0..500).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(got.len(), 500);
        assert!(got.iter().enumerate().all(|(i, &v)| v == i));
    }
}
