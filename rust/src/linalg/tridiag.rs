//! Symmetric tridiagonal eigensolver (QL with implicit shifts — a port of
//! EISPACK's `tql2`, the same routine ARPACK leans on for its projected
//! problem). This is the small replicated eigenproblem at the heart of the
//! Lanczos truncated SVD.

/// Eigendecomposition of the symmetric tridiagonal matrix with diagonal
/// `d` (length n) and off-diagonal `e` (length n-1).
///
/// Returns `(eigenvalues ascending, eigenvectors)` where `vectors[j]` is
/// the eigenvector for `values[j]` (each of length n).
pub fn tql2(d: &[f64], e: &[f64]) -> crate::Result<(Vec<f64>, Vec<Vec<f64>>)> {
    let n = d.len();
    anyhow::ensure!(n > 0, "empty tridiagonal");
    anyhow::ensure!(e.len() + 1 == n, "off-diagonal length must be n-1");

    let mut d = d.to_vec();
    // work array: off-diagonals shifted to e[0..n-1], e[n-1] = 0
    let mut e_work = vec![0.0; n];
    e_work[..n - 1].copy_from_slice(e);

    // z starts as identity; accumulates rotations (columns = eigenvectors)
    let mut z = vec![vec![0.0; n]; n];
    for (i, row) in z.iter_mut().enumerate() {
        row[i] = 1.0;
    }

    for l in 0..n {
        let mut iter = 0;
        loop {
            // find a small off-diagonal element to split at
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e_work[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            anyhow::ensure!(iter <= 50, "tql2 failed to converge at index {l}");

            // implicit shift from the 2x2 at l
            let mut g = (d[l + 1] - d[l]) / (2.0 * e_work[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e_work[l] / (g + r.copysign(g));
            let mut s = 1.0;
            let mut c = 1.0;
            let mut p = 0.0;

            for i in (l..m).rev() {
                let mut f = s * e_work[i];
                let b = c * e_work[i];
                r = f.hypot(g);
                e_work[i + 1] = r;
                if r == 0.0 {
                    // recover from underflow
                    d[i + 1] -= p;
                    e_work[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate the rotation into z
                for zrow in z.iter_mut() {
                    f = zrow[i + 1];
                    zrow[i + 1] = s * zrow[i] + c * f;
                    zrow[i] = c * zrow[i] - s * f;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e_work[l] = g;
            e_work[m] = 0.0;
        }
    }

    // sort ascending, carrying eigenvectors along
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    let values: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let vectors: Vec<Vec<f64>> = idx
        .iter()
        .map(|&j| z.iter().map(|row| row[j]).collect())
        .collect();
    Ok((values, vectors))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn check_decomposition(d: &[f64], e: &[f64], tol: f64) {
        let n = d.len();
        let (vals, vecs) = tql2(d, e).unwrap();
        // ascending
        for w in vals.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
        for (lam, v) in vals.iter().zip(&vecs) {
            // residual ‖T v − λ v‖
            let mut res = 0.0f64;
            for i in 0..n {
                let mut tv = d[i] * v[i];
                if i > 0 {
                    tv += e[i - 1] * v[i - 1];
                }
                if i + 1 < n {
                    tv += e[i] * v[i + 1];
                }
                res = res.max((tv - lam * v[i]).abs());
            }
            assert!(res < tol, "residual {res}");
            // unit norm
            let nrm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((nrm - 1.0).abs() < 1e-10);
        }
        // trace preserved
        let tr: f64 = d.iter().sum();
        let sum: f64 = vals.iter().sum();
        assert!((tr - sum).abs() < tol * n as f64);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3
        let (vals, _) = tql2(&[2.0, 2.0], &[1.0]).unwrap();
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_passthrough() {
        let (vals, _) = tql2(&[3.0, 1.0, 2.0], &[0.0, 0.0]).unwrap();
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn random_matrices_various_sizes() {
        let mut rng = Rng::new(9);
        for n in [1usize, 2, 3, 8, 33, 100] {
            let d: Vec<f64> = (0..n).map(|_| rng.normal() * 3.0).collect();
            let e: Vec<f64> = (0..n.saturating_sub(1)).map(|_| rng.normal()).collect();
            check_decomposition(&d, &e, 1e-9);
        }
    }

    #[test]
    fn clustered_eigenvalues() {
        // nearly-degenerate diagonal with weak coupling
        let d = vec![1.0, 1.0 + 1e-12, 1.0 + 2e-12, 5.0];
        let e = vec![1e-13, 1e-13, 1e-13];
        check_decomposition(&d, &e, 1e-9);
    }
}
