//! The MPI stand-in (DESIGN.md §2).
//!
//! Alchemist's workers are MPI ranks; this module gives the rust workers
//! the same programming model: a [`Communicator`] with point-to-point
//! send/recv plus the collective algorithms the numerics need (barrier,
//! binomial-tree broadcast/reduce, ring allreduce, gather/scatter/
//! allgather). The collectives are implemented *over* send/recv — the real
//! algorithms, not shared-memory shortcuts — so their communication volume
//! is faithful and the SimClock can charge modeled interconnect time per
//! message (the box has one core; see `metrics::simclock`).
//!
//! Groups come in two flavors: [`LocalComm::group`] builds the full pool,
//! and [`LocalComm::subgroup`] builds an independent communicator over an
//! arbitrary rank subset — the substrate for session-scoped worker groups
//! (disjoint sessions collect over disjoint fabrics, so they never
//! serialize on each other).
//!
//! **Failure propagation (protocol v5).** Collectives are *fallible*: a
//! rank that panics, errors, or is hard-cancelled cannot contribute to its
//! peers' collectives, and without intervention those peers would block in
//! an allreduce forever (the availability bug the Cray deployment
//! follow-up calls out). The fix is group *poisoning*: when a rank fails,
//! its worker loop calls [`Communicator::poison`] with the failed rank,
//! and every peer blocked in — or later entering — `recv`/`barrier` wakes
//! immediately with [`CommError::PeerFailed`] instead of waiting for a
//! contribution that will never come. The [`algorithms`] are all
//! `Result`-returning and propagate the first failure; the
//! [`algorithms::infallible`] wrappers exist for callers whose groups can
//! never be poisoned (single-rank groups, direct library use, benches).

//!
//! **Transports (protocol v8).** Two implementations exist:
//! [`LocalComm`] (threads in one process, lock-free mailboxes) and
//! [`netcomm::TcpComm`] (worker ranks as separate OS processes joined by
//! a coordinator-brokered TCP mesh — see `docs/fabric.md`). The
//! [`Fabric`] trait is the server-side superset the dispatcher manages:
//! a `Communicator` that can also be `reset` between tasks.

pub mod algorithms;
pub mod local;
pub mod netcomm;

pub use algorithms::{
    allgather, allreduce_sum, broadcast, gather, reduce_sum, scatter,
};
pub use local::LocalComm;
pub use netcomm::{loopback_group, FabricOptions, MeshAcceptor, TcpComm};

/// Why a collective operation failed. Only the coordinator's fault
/// machinery produces these: outside it (direct library use, tests) the
/// fallible collectives cannot fail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommError {
    /// The group is poisoned because group-local `rank` failed (panicked
    /// or returned an error) while its peers were — or were about to be —
    /// blocked in a collective. Errors carrying this variant are
    /// *collateral*: the named rank is the root cause, not the rank that
    /// observed the error.
    PeerFailed { rank: usize },
    /// The group was poisoned by a hard cancel (a `CancelTask
    /// { hard_after_ms }` escalation or forced session teardown), not by
    /// a rank failure.
    Cancelled,
    /// [`Communicator::recv_deadline`] elapsed without a matching
    /// message; the group is *not* poisoned.
    Timeout { from: usize, tag: u64 },
}

impl CommError {
    /// Whether this error is *collateral* — the observing rank unwound
    /// because the group was already poisoned, rather than failing on its
    /// own. Both the worker loop (to avoid re-poisoning over the root
    /// cause) and the dispatcher's failure aggregation (to report the
    /// root cause, not its blast radius) classify through this one
    /// predicate so they can never disagree. `Timeout` is a local
    /// failure, not collateral.
    pub fn is_collateral(&self) -> bool {
        matches!(self, CommError::PeerFailed { .. } | CommError::Cancelled)
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::PeerFailed { rank } => {
                write!(f, "collective aborted: peer rank {rank} failed")
            }
            CommError::Cancelled => {
                write!(f, "collective aborted: task hard-cancelled")
            }
            CommError::Timeout { from, tag } => {
                write!(f, "recv deadline expired waiting for rank {from} (tag {tag})")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// What poisoned a group (see [`Communicator::poison`]). Stored once per
/// fabric; the first poisoner wins, so the recorded cause is the *root*
/// cause even when collateral failures cascade afterwards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoisonCause {
    /// Group-local rank that failed on its own (panic or error).
    RankFailed(usize),
    /// Deadline escalation / teardown: no rank failed, the driver pulled
    /// the plug.
    HardCancel,
}

impl PoisonCause {
    /// The error every blocked/arriving rank observes for this poison.
    pub fn to_err(self) -> CommError {
        match self {
            PoisonCause::RankFailed(rank) => CommError::PeerFailed { rank },
            PoisonCause::HardCancel => CommError::Cancelled,
        }
    }
}

/// Point-to-point message transport between ranks of one worker group.
///
/// Messages are `Vec<f64>` (every payload in this system is double
/// precision) addressed by `(peer, tag)`; tags keep concurrent collectives
/// from interleaving. Implementations must deliver messages from the same
/// (sender, tag) in order.
///
/// Receive paths and the barrier are fallible: once the group is poisoned
/// (see [`Communicator::poison`]) every blocked or arriving rank observes
/// the poison as a [`CommError`] instead of blocking forever. `send` stays
/// infallible — it is buffered and never blocks, and a send into a
/// poisoned group is simply never received.
pub trait Communicator: Send {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;
    /// Non-blocking buffered send.
    fn send(&self, to: usize, tag: u64, data: Vec<f64>);
    /// Blocking receive; wakes with the poison error if the group is (or
    /// becomes) poisoned.
    fn recv(&self, from: usize, tag: u64) -> Result<Vec<f64>, CommError>;
    /// [`Communicator::recv`] with a deadline: returns
    /// [`CommError::Timeout`] if no matching message arrives within
    /// `timeout` (poison still wins over the timeout).
    fn recv_deadline(
        &self,
        from: usize,
        tag: u64,
        timeout: std::time::Duration,
    ) -> Result<Vec<f64>, CommError>;
    /// Block until every rank arrives — or the group is poisoned, in
    /// which case every waiter (and every later arriver) errors instead.
    fn barrier(&self) -> Result<(), CommError>;
    /// Poison the whole group: every rank blocked in (or later calling)
    /// `recv`/`recv_deadline`/`barrier` errors with `cause`'s
    /// [`CommError`]. Idempotent; the first cause is kept (it is the root
    /// cause — later poisons are collateral).
    fn poison(&self, cause: PoisonCause);
    /// The group's current poison, if any.
    fn poison_cause(&self) -> Option<PoisonCause>;
    /// Modeled communication seconds charged to this rank so far (for
    /// simulated-cluster-time accounting); implementations without a cost
    /// model return 0.
    fn sim_comm_secs(&self) -> f64 {
        0.0
    }
}

/// Tag-space layout so nested collectives never collide: each collective
/// invocation passes a distinct `base` tag and algorithms offset within
/// a 2^16 window. The [`algorithms`] debug-assert both halves of the
/// contract: `base` must be `TAG_WINDOW`-aligned and every per-algorithm
/// offset must stay inside the window.
pub const TAG_WINDOW: u64 = 1 << 16;

/// A [`Communicator`] as the server's dispatcher manages it: collectives
/// during a task, plus a `reset` between tasks that drops stragglers and
/// clears poison so the next task starts on a clean fabric. Both
/// transports implement it; sessions hold `Arc<dyn Fabric>` so a worker
/// loop cannot tell (and must not care) which transport its group is on.
pub trait Fabric: Communicator + Send + Sync {
    /// Clear all transient group state between tasks (queued messages,
    /// poison, barrier generations).
    fn reset(&self);
    /// This fabric as a plain [`Communicator`] — the view handed to
    /// library routines. (Explicit because trait-object upcasting is
    /// newer than this crate's compiler floor.)
    fn as_comm(&self) -> &dyn Communicator;
}
