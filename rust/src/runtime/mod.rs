//! Artifact runtime: load the AOT manifest and execute artifacts through
//! the PJRT stand-in ([`pjrtsim`]).
//!
//! A [`Runtime`] owns one client plus a lazily-compiled executable cache
//! keyed by artifact name; `compute::XlaEngine` resolves (op, engine,
//! dims) → artifact through the [`manifest`] and calls [`Runtime::run`].
//!
//! Real PJRT wrapper types hold raw pointers and are not `Send`, so each
//! worker thread owns its own `Runtime` — the same shape as MPI ranks
//! each holding their own library context. The stand-in keeps that
//! discipline (per-thread construction, nothing shared) so swapping a
//! real PJRT client back in is a local change to [`pjrtsim`]'s three
//! types, not a re-architecture.
//!
//! Interchange is the manifest's op + static shape tuple; the exported
//! HLO text (`*.hlo.txt`, see `python/compile/aot.py`) is provenance the
//! stand-in does not interpret — see `pjrtsim`'s module docs for the
//! honest scope of the substitution.

pub mod manifest;
pub mod pjrtsim;

pub use manifest::{ArtifactEntry, Manifest};

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::Context;

/// An executed output: flat row-major data plus its shape.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f64>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f64>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor { dims, data }
    }
}

/// An operand resident on the device — upload once, execute many
/// (§Perf: re-uploading the static Gram panel every CG iteration was the
/// top bottleneck before buffer caching).
pub struct DeviceBuf {
    buf: pjrtsim::Buffer,
    pub dims: Vec<usize>,
}

impl DeviceBuf {
    pub fn bytes(&self) -> usize {
        self.dims.iter().product::<usize>() * 8
    }
}

pub struct Runtime {
    client: pjrtsim::Client,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, pjrtsim::LoadedExecutable>,
    /// Cumulative seconds spent inside `execute` (perf accounting).
    pub exec_secs: f64,
    /// Number of `run` calls (perf accounting).
    pub exec_calls: u64,
}

impl Runtime {
    /// Load the manifest from `dir` and create the client. Executables
    /// compile lazily on first use.
    pub fn load(dir: &std::path::Path) -> crate::Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.txt")).with_context(|| {
            format!("loading artifact manifest from {dir:?} (run `make artifacts`)")
        })?;
        let client = pjrtsim::Client::cpu().context("creating PJRT stand-in client")?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: HashMap::new(),
            exec_secs: 0.0,
            exec_calls: 0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the named artifact.
    fn executable(&mut self, name: &str) -> crate::Result<&pjrtsim::LoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .by_name(name)
                .with_context(|| format!("artifact {name:?} not in manifest"))?;
            let t0 = std::time::Instant::now();
            let exe = self
                .client
                .compile(entry)
                .with_context(|| format!("compiling {name} from {:?}", self.dir))?;
            log::debug!(
                "compiled artifact {name} in {:.3}s",
                t0.elapsed().as_secs_f64()
            );
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` on the given inputs (shape-checked against
    /// the manifest). Returns the tuple elements as [`Tensor`]s.
    pub fn run(
        &mut self,
        name: &str,
        inputs: &[(&[f64], &[usize])],
    ) -> crate::Result<Vec<Tensor>> {
        let entry = self
            .manifest
            .by_name(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == entry.in_shapes.len(),
            "artifact {name} wants {} inputs, got {}",
            entry.in_shapes.len(),
            inputs.len()
        );
        for (i, (data, dims)) in inputs.iter().enumerate() {
            anyhow::ensure!(
                dims == &entry.in_shapes[i].as_slice(),
                "artifact {name} input {i}: want shape {:?}, got {dims:?}",
                entry.in_shapes[i]
            );
            anyhow::ensure!(
                data.len() == dims.iter().product::<usize>(),
                "artifact {name} input {i}: data/shape mismatch"
            );
        }

        let t0 = std::time::Instant::now();
        let exe = self.executable(name)?;
        let datas: Vec<&[f64]> = inputs.iter().map(|(d, _)| *d).collect();
        let out = exe
            .execute(&datas)
            .with_context(|| format!("executing {name}"))?;
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;

        anyhow::ensure!(
            out.len() == entry.out_shapes.len(),
            "artifact {name}: manifest promises {} outputs, got {}",
            entry.out_shapes.len(),
            out.len()
        );
        for (t, dims) in out.iter().zip(&entry.out_shapes) {
            anyhow::ensure!(
                &t.dims == dims,
                "artifact {name}: output shape {:?}, want {dims:?}",
                t.dims
            );
        }
        Ok(out)
    }

    /// Convenience for the common single-output case.
    pub fn run1(
        &mut self,
        name: &str,
        inputs: &[(&[f64], &[usize])],
    ) -> crate::Result<Tensor> {
        let mut out = self.run(name, inputs)?;
        anyhow::ensure!(out.len() == 1, "artifact {name} has {} outputs", out.len());
        Ok(out.pop().unwrap())
    }

    /// Upload an operand to the device once; reuse across many executions
    /// (static operands like the CG Gram panel — §Perf).
    pub fn upload(&self, data: &[f64], dims: &[usize]) -> crate::Result<DeviceBuf> {
        anyhow::ensure!(
            data.len() == dims.iter().product::<usize>(),
            "upload: data/shape mismatch"
        );
        Ok(DeviceBuf {
            buf: pjrtsim::Buffer { data: data.to_vec() },
            dims: dims.to_vec(),
        })
    }

    /// Execute with device-resident operands (single-output artifacts).
    pub fn run1_b(&mut self, name: &str, inputs: &[&DeviceBuf]) -> crate::Result<Tensor> {
        let entry = self
            .manifest
            .by_name(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == entry.in_shapes.len(),
            "artifact {name} wants {} inputs, got {}",
            entry.in_shapes.len(),
            inputs.len()
        );
        for (i, b) in inputs.iter().enumerate() {
            anyhow::ensure!(
                b.dims == entry.in_shapes[i],
                "artifact {name} input {i}: want shape {:?}, got {:?}",
                entry.in_shapes[i],
                b.dims
            );
        }
        let t0 = std::time::Instant::now();
        let exe = self.executable(name)?;
        let datas: Vec<&[f64]> = inputs.iter().map(|b| b.buf.data.as_slice()).collect();
        let mut out = exe
            .execute(&datas)
            .with_context(|| format!("executing {name}"))?;
        self.exec_secs += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        anyhow::ensure!(out.len() == 1, "run1_b expects a single output");
        Ok(out.pop().unwrap())
    }
}
