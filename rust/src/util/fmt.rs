//! Human-readable formatting for sizes and durations.

/// Format a byte count: `1536 -> "1.5 KiB"`.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format seconds adaptively: `0.00042 -> "0.42 ms"`, `75.3 -> "75.3 s"`.
pub fn secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 100.0 {
        format!("{s:.2} s")
    } else {
        format!("{s:.1} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(1536), "1.5 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn secs_ranges() {
        assert!(secs(0.0000004).ends_with("µs"));
        assert!(secs(0.004).ends_with("ms"));
        assert!(secs(4.0).ends_with("s"));
    }
}
