//! Alchemist worker: one rank of the server's worker pool.
//!
//! Each worker owns (a) a slot in the shared matrix-store array — written
//! by its data-socket threads during ingest, read by routines during
//! compute — and (b) a command loop thread that executes library routines
//! with the communicator of whichever *session group* the task belongs
//! to. Workers are allocated to sessions exclusively: the driver binds a
//! session-scoped [`Fabric`] endpoint (a [`crate::collectives::LocalComm`]
//! for in-process ranks, a [`crate::collectives::TcpComm`] in a worker
//! process — protocol v8) into [`WorkerShared::sessions`] at handshake
//! time and removes it at teardown, so tasks from sessions holding
//! disjoint groups run concurrently on disjoint worker threads.
//! Since protocol v9 the command loop also runs tasks of the *same*
//! session concurrently: each `RunTask` executes on its own thread with
//! its own engine (real PJRT handles are not `Send`, so engines are
//! built on the thread that uses them), each leasing a fresh client
//! queue of the server's work-stealing compute pool, and each seeing the
//! group through a [`crate::collectives::LaneComm`] view so concurrent
//! tasks use disjoint tag spaces. While a task runs, its cooperative
//! [`crate::tasks::CancelToken`] is installed into its engine so the
//! kernels themselves check in at panel boundaries (a hard cancel lands
//! within one MC-panel even in routines that never poll their scope).
//!
//! Data-socket threads never serialize on a store-wide lock: the
//! [`MatrixStore`] hands out `Arc<Block>` handles under a short read
//! lock, ingest copies synchronize per block stripe, and pull replies
//! stream borrowed spans of sealed blocks straight into the socket
//! buffer (see `coordinator::store` and `docs/data-plane.md`).

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::collectives::{CommError, Communicator, Fabric, LaneComm, PoisonCause};
use crate::compute::{build_engine_with_pool, ThreadPool};
use crate::config::Config;
use crate::distmat::RowBlockLayout;
use crate::net::Framed;
use crate::protocol::{max_rows_per_frame_for, DataMsg, DataMsgRef, DataMsgView, Params};
use crate::util::timer::thread_cpu_secs;

use super::registry::{Library, WorkerCtx};
use super::store::MatrixStore;

/// State shared between the worker thread, its data-socket threads, and
/// the driver (which allocates/seals/frees blocks and binds sessions
/// directly).
pub struct WorkerShared {
    /// Global rank in the server's worker pool.
    pub rank: usize,
    /// Interior-locked (lookups take a short read lock; payload writes
    /// synchronize per block) — concurrent data-socket threads do not
    /// contend here.
    pub store: MatrixStore,
    /// `host:port` of this worker's data listener.
    pub data_addr: Mutex<String>,
    /// session id → this worker's endpoint in that session's group
    /// communicator (bound at handshake, removed at teardown). The
    /// endpoint's [`Communicator::rank`] is the session's group-local
    /// rank for this worker.
    pub sessions: Mutex<HashMap<u64, Arc<dyn Fabric>>>,
}

/// Output metadata a rank reports back to the driver after a task (the
/// blocks themselves are already in the store).
#[derive(Debug, Clone)]
pub struct OutputMeta {
    pub id: u64,
    pub name: String,
    pub rows: u64,
    pub cols: u64,
    /// The output's row-block layout across the group. Reported with the
    /// reply (not re-read from the store) because with process-separated
    /// ranks (protocol v8) the coordinator holds no store and must learn
    /// the layout over the wire.
    pub layout: RowBlockLayout,
}

/// A completed task on one rank.
pub struct TaskReply {
    pub outputs: Vec<OutputMeta>,
    pub scalars: Params,
    /// Library timing laps + `cpu_busy` + `comm_sim` added by the loop.
    pub timings: Vec<(String, f64)>,
}

/// Commands the driver sends to a worker thread.
pub enum WorkerCmd {
    RunTask {
        /// Session whose bound group communicator executes the task.
        session_id: u64,
        lib: Arc<dyn Library>,
        routine: String,
        params: Params,
        /// Output matrix `i` is stored under id `out_base + i`.
        out_base: u64,
        /// Ids reserved for outputs: a routine returning more than
        /// `out_span` matrices fails *before* inserting anything (it
        /// would collide with ids handed out after the reservation).
        out_span: u64,
        /// Intra-rank engine parallelism for this task, clamped at
        /// session admission so `granted_workers × engine_threads ≤
        /// available cores` (see `Config::engine_threads_for_group`).
        engine_threads: usize,
        /// Cooperative cancel token + this rank's progress slot.
        scope: crate::tasks::TaskScope,
        reply: mpsc::Sender<crate::Result<TaskReply>>,
    },
    Shutdown,
}

/// The worker command loop. Runs until `Shutdown`. `pool` is this rank's
/// client queue of the server's shared compute pool (`None` — the tcp
/// worker-process case — builds a process-local root pool instead).
///
/// Since protocol v9 each `RunTask` executes on its **own thread** with
/// its **own engine** riding a fresh client queue of the pool, so up to
/// `scheduler.tasks_per_group` tasks of one session (each on its own
/// communicator tag lane) run concurrently on this rank. Engines are
/// per-task because a task's cancel token is installed into its engine
/// for kernel-level check-ins — concurrent tasks must not share one
/// token slot — and real PJRT handles are not `Send`, so each engine is
/// built on the thread that uses it.
pub fn worker_main(
    shared: Arc<WorkerShared>,
    cfg: Config,
    rx: mpsc::Receiver<WorkerCmd>,
    pool: Option<ThreadPool>,
) {
    let rank = shared.rank;
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // one root thread set either way: per-task engines lease client
    // queues from it instead of spawning private pools per task
    let pool = pool.unwrap_or_else(|| ThreadPool::new(avail));
    let mut tasks: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            WorkerCmd::Shutdown => break,
            WorkerCmd::RunTask {
                session_id,
                lib,
                routine,
                params,
                out_base,
                out_span,
                engine_threads,
                scope,
                reply,
            } => {
                // looked up on the command thread (not the task thread)
                // so a session unbound between dispatch and spawn still
                // yields a deterministic per-rank error
                let comm = shared.sessions.lock().unwrap().get(&session_id).cloned();
                // this task's slice of the shared pool: its own client
                // queue, capped at the task's engine-thread grant
                let task_pool = pool.client(engine_threads.max(1));
                let shared = Arc::clone(&shared);
                let cfg = cfg.clone();
                tasks.retain(|h| !h.is_finished());
                tasks.push(std::thread::spawn(move || {
                    run_one_task(
                        &shared, &cfg, rank, session_id, lib, &routine, params,
                        out_base, out_span, engine_threads, scope, reply, comm,
                        task_pool,
                    );
                }));
            }
        }
    }
    // Shutdown: every in-flight task has its cancel token set by the
    // driver's drain; join them so the process never exits under a
    // routine mid-collective
    for h in tasks {
        let _ = h.join();
    }
    log::debug!("worker {rank} exiting");
}

/// Execute one task on its own thread: build the task's engine, wrap the
/// group fabric in the task's tag-lane view, run the routine, insert
/// outputs, and reply. Failure propagation is lane-scoped (protocol v9):
/// a rank that fails on its own poisons only its task's lane, so a
/// sibling task running concurrently on the same group is untouched.
#[allow(clippy::too_many_arguments)]
fn run_one_task(
    shared: &WorkerShared,
    cfg: &Config,
    rank: usize,
    session_id: u64,
    lib: Arc<dyn Library>,
    routine: &str,
    params: Params,
    out_base: u64,
    out_span: u64,
    engine_threads: usize,
    scope: crate::tasks::TaskScope,
    reply: mpsc::Sender<crate::Result<TaskReply>>,
    comm: Option<Arc<dyn Fabric>>,
    task_pool: ThreadPool,
) {
    // a panicking routine must not kill this task thread silently: a
    // dead rank never answers its reply channel and (worse) never
    // reaches its collectives, wedging live peers. Catching the panic
    // turns it into a per-rank Failed reply — and poisoning the lane
    // (below) releases any peer already blocked in a collective this
    // rank will never join, with `CommError::PeerFailed { rank }`
    // naming this rank as the root cause.
    let result = match comm.clone() {
        None => Err(anyhow::anyhow!(
            "rank {rank}: session {session_id} holds no group here"
        )),
        Some(comm) => std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || -> crate::Result<TaskReply> {
                let mut engine = build_engine_with_pool(cfg, Some(task_pool))?;
                // clamped at dispatch; the engine's queue cap tracks it
                engine.set_threads(engine_threads.max(1));
                // kernel-level cancellation check-ins for the duration
                // of this task (the engine dies with the task, so there
                // is nothing to uninstall)
                engine.set_cancel(Some(scope.token().clone()));
                let local_rank = comm.rank();
                // the task's view of the group: every tag offset into
                // its lane window, so a concurrent sibling's traffic
                // can never collide with ours. Lane 0 (pre-v9 dispatch
                // or detached use) keeps the raw fabric.
                let lane_view;
                let comm_view: &dyn Communicator = if scope.lane() > 0 {
                    lane_view = LaneComm::new(Arc::clone(&comm), scope.lane());
                    &lane_view
                } else {
                    comm.as_comm()
                };
                let cpu0 = thread_cpu_secs();
                let sim0 = comm.sim_comm_secs();
                let mut ctx = WorkerCtx {
                    rank: local_rank,
                    comm: comm_view,
                    engine: engine.as_mut(),
                    store: &shared.store,
                    config: cfg,
                    scope: &scope,
                };
                let out = lib.run(routine, &params, &mut ctx)?;
                let cpu_busy = (thread_cpu_secs() - cpu0).max(0.0);
                let comm_sim = comm.sim_comm_secs() - sim0;

                // the reservation is a hard cap: exceeding it would
                // silently collide with matrix ids allocated after this
                // task's window — fail before inserting anything
                anyhow::ensure!(
                    out.matrices.len() as u64 <= out_span,
                    "routine {routine} produced {} outputs, exceeding \
                     the task's reservation of {out_span} ids \
                     (scheduler.max_task_outputs)",
                    out.matrices.len()
                );
                let mut metas = Vec::with_capacity(out.matrices.len());
                for (i, m) in out.matrices.into_iter().enumerate() {
                    let id = out_base + i as u64;
                    metas.push(OutputMeta {
                        id,
                        name: m.name.clone(),
                        rows: m.layout.rows as u64,
                        cols: m.layout.cols as u64,
                        layout: m.layout.clone(),
                    });
                    shared.store.insert(
                        id,
                        &m.name,
                        m.layout,
                        m.local,
                        local_rank,
                        session_id,
                    )?;
                }
                let mut timings = out.timings;
                timings.push(("cpu_busy".into(), cpu_busy));
                timings.push(("comm_sim".into(), comm_sim));
                Ok(TaskReply { outputs: metas, scalars: out.scalars, timings })
            },
        ))
        .unwrap_or_else(|panic| {
            let what = panic
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            Err(anyhow::anyhow!("routine {routine} panicked: {what}"))
        }),
    };
    // failure propagation: a rank that failed on its own (not as
    // collateral of someone else's failure) poisons the task's lane so
    // peers blocked in — or about to enter — one of its collectives
    // unwind promptly instead of waiting for a contribution that will
    // never come; a sibling task's lanes keep flowing. MUST happen
    // before the reply send: the executor retires the lane once every
    // rank has replied, and a poison landing after that retirement is
    // dropped. Collateral errors (CommError) never re-poison, so the
    // recorded root cause stays the first failing rank. Lane-0 tasks
    // (pre-v9 dispatch) fall back to the group-wide poison.
    if let (Err(e), Some(comm)) = (&result, &comm) {
        let collateral = e
            .downcast_ref::<CommError>()
            .is_some_and(CommError::is_collateral);
        if !collateral {
            let cause = PoisonCause::RankFailed(comm.rank());
            if scope.lane() > 0 {
                comm.poison_lane(scope.lane(), cause);
            } else {
                comm.poison(cause);
            }
        }
    }
    let failed = result.is_err();
    let cancelled = scope.is_cancelled();
    let _ = reply.send(result);
    if failed && !cancelled {
        log::warn!("rank {rank}: task {routine} failed");
    } else if failed {
        log::debug!("rank {rank}: task {routine} cancelled");
    }
}

/// Data-plane ownership gate: a connection may only touch matrices of
/// the session it performed its `DataHandshake` as (tenant isolation —
/// matrix ids are sequential and trivially guessable).
fn check_session(owner: u64, conn_session: Option<u64>, id: u64) -> crate::Result<()> {
    match conn_session {
        Some(s) if s == owner => Ok(()),
        Some(s) => anyhow::bail!("matrix {id} is not owned by session {s}"),
        None => anyhow::bail!("data handshake required before accessing matrix {id}"),
    }
}

/// What the connection loop does after a frame's borrow of the receive
/// buffer ends (streaming replies cannot be produced while the decoded
/// view still borrows the link).
enum Action {
    Nothing,
    Reply(DataMsg),
    ServePull {
        matrix_id: u64,
        start_row: u64,
        nrows: u32,
        start_col: u64,
        sel_cols: u32,
    },
    Close,
}

/// Stream one ranged `PullRows` reply: validate the whole span up front
/// (the stream is all-or-nothing — a single `DataError`, or `RowsData`*
/// followed by `PullDone`), then write spans of the sealed block straight
/// into the socket buffer, `frame_rows` rows per frame. Heap and mapped
/// payloads are served zero-copy (the frame borrows the block / the page
/// cache); spilled payloads stream frame-sized reads off the spill file,
/// so a pull never materializes more than one frame of a spilled block.
/// A v7 column range (`sel_cols > 0`) gathers the selected columns into a
/// reusable scratch buffer — one copy, no per-frame allocation.
fn serve_pull(
    shared: &WorkerShared,
    framed: &mut Framed<TcpStream, TcpStream>,
    conn_session: Option<u64>,
    matrix_id: u64,
    start_row: u64,
    nrows: u32,
    start_col: u64,
    sel_cols: u32,
    frame_rows: usize,
) -> crate::Result<()> {
    let prep = (|| -> crate::Result<(Arc<super::store::Block>, usize, usize, usize)> {
        anyhow::ensure!(nrows > 0, "zero-row pull of matrix {matrix_id}");
        let block = shared.store.get(matrix_id)?;
        check_session(block.session, conn_session, matrix_id)?;
        // whole-range validation (sealed + bounds) before the first
        // frame, without touching payload bytes (a spilled block must
        // not be read twice just to validate)
        block.validate_span(start_row, nrows as usize)?;
        let ncols = block.layout.cols;
        let (col0, width) = if sel_cols == 0 {
            anyhow::ensure!(
                start_col == 0,
                "matrix {matrix_id}: start_col {start_col} without sel_cols"
            );
            (0usize, ncols)
        } else {
            let end_col = start_col
                .checked_add(sel_cols as u64)
                .ok_or_else(|| anyhow::anyhow!("column range overflows"))?;
            anyhow::ensure!(
                end_col <= ncols as u64,
                "matrix {matrix_id}: columns [{start_col}, {end_col}) outside \
                 width {ncols}"
            );
            (start_col as usize, sel_cols as usize)
        };
        // clamp rows-per-frame so header + payload stays under the frame
        // cap for the SELECTED width: a wide pull must fail HERE (one
        // clean DataError) or not at all — never mid-stream after
        // RowsData frames were queued, which would break the
        // all-or-nothing reply contract with an opaque I/O error
        let cap_rows = max_rows_per_frame_for(width, crate::net::MAX_FRAME as usize)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "matrix {matrix_id}: one row of {width} cols exceeds the {} \
                     byte frame cap",
                    crate::net::MAX_FRAME,
                )
            })?;
        Ok((block, col0, width, frame_rows.clamp(1, cap_rows)))
    })();
    let (block, col0, width, frame_rows) = match prep {
        Ok(b) => b,
        Err(e) => {
            return framed.send_data_flush(&DataMsg::DataError { message: e.to_string() })
        }
    };
    // ncols comes from the block's layout, never derived from payload
    // lengths (a zero-row request cannot reach here anyway)
    let ncols = block.layout.cols;
    // column-gather scratch, reused across frames (full-width pulls
    // never touch it — their frames borrow the span directly)
    let mut scratch: Vec<f64> = Vec::new();
    let mut row = start_row;
    let end = start_row + nrows as u64;
    while row < end {
        let n = frame_rows.min((end - row) as usize);
        // bounds were validated above, so a failure here is spill-file
        // I/O — unrecoverable mid-stream, so the connection drops (the
        // client sees a truncated reply, not silent corruption)
        let span = block.read_span(row, n)?;
        if width == ncols {
            framed.send_data_ref(&DataMsgRef::RowsData {
                matrix_id,
                start_row: row,
                nrows: n as u32,
                ncols: ncols as u32,
                data: &span[..],
            })?;
        } else {
            scratch.clear();
            scratch.reserve(n * width);
            for r in 0..n {
                let base = r * ncols + col0;
                scratch.extend_from_slice(&span[base..base + width]);
            }
            framed.send_data_ref(&DataMsgRef::RowsData {
                matrix_id,
                start_row: row,
                nrows: n as u32,
                ncols: width as u32,
                data: &scratch,
            })?;
        }
        row += n as u64;
    }
    framed.send_data(&DataMsg::PullDone { matrix_id })?;
    // one flush per ranged request, not per frame
    framed.flush()
}

/// Handle one executor's data connection (runs on its own thread; several
/// executors can stream to the same worker concurrently — the paper's
/// asynchronous many-to-many transfer pattern). The connection binds to
/// one session at `DataHandshake` and may only touch that session's
/// matrices.
pub fn handle_data_conn(shared: &WorkerShared, stream: TcpStream, cfg: &Config) {
    let mut framed = match Framed::tcp(stream, cfg.transfer.buf_bytes) {
        Ok(f) => f,
        Err(e) => {
            log::warn!("rank {}: data conn setup failed: {e}", shared.rank);
            return;
        }
    };
    let mut conn_session: Option<u64> = None;
    // pull-reply frame granularity: negotiated at DataHandshake, clamped
    // by the server-side transfer limits
    let mut frame_rows = cfg.transfer.rows_per_frame.max(1);
    // first failing PushRows per matrix replies immediately (one bounded
    // frame); repeats are latched silently and re-surfaced at PushDone.
    // A streaming client reads nothing until PushDone, so replying to
    // EVERY bad frame would fill the socket buffers on both sides and
    // deadlock the connection.
    let mut push_errors: HashMap<u64, String> = HashMap::new();
    loop {
        // decode in place (payloads borrow the link's receive buffer);
        // replies are sent after the borrow ends
        let action = {
            let msg = match framed.recv_data_view() {
                Ok(m) => m,
                Err(_) => return, // peer closed
            };
            match msg {
                DataMsgView::PushRows { matrix_id, start_row, ncols, payload, .. } => {
                    // single-copy ingest: payload bytes go straight from
                    // the receive buffer into the block's storage
                    let res = (|| -> crate::Result<()> {
                        let block = shared.store.get(matrix_id)?;
                        check_session(block.session, conn_session, matrix_id)?;
                        block.write_rows_bytes(start_row, ncols as usize, payload)
                    })();
                    match res {
                        Ok(()) => Action::Nothing, // streaming: acks only at PushDone
                        Err(e) if push_errors.contains_key(&matrix_id) => {
                            log::debug!(
                                "rank {}: suppressed repeat push error on matrix \
                                 {matrix_id}: {e}",
                                shared.rank
                            );
                            Action::Nothing
                        }
                        Err(e) => {
                            let message = e.to_string();
                            push_errors.insert(matrix_id, message.clone());
                            Action::Reply(DataMsg::DataError { message })
                        }
                    }
                }
                DataMsgView::RowsData { .. } => Action::Reply(DataMsg::DataError {
                    message: "unexpected RowsData on a worker's data socket".into(),
                }),
                DataMsgView::Other(msg) => match msg {
                    DataMsg::DataHandshake { session_id, rows_per_frame, .. } => {
                        // reply with the session's group-local rank for
                        // this worker (executors index worker addresses
                        // per session group); sessions holding no group
                        // here are rejected
                        let local = shared
                            .sessions
                            .lock()
                            .unwrap()
                            .get(&session_id)
                            .map(|c| c.rank());
                        match local {
                            Some(local) => {
                                conn_session = Some(session_id);
                                frame_rows =
                                    cfg.transfer.effective_frame_rows(rows_per_frame);
                                Action::Reply(DataMsg::DataHandshakeAck {
                                    worker_rank: local as u32,
                                })
                            }
                            None => Action::Reply(DataMsg::DataError {
                                message: format!(
                                    "session {session_id} holds no group on worker {}",
                                    shared.rank
                                ),
                            }),
                        }
                    }
                    DataMsg::PushDone { matrix_id } => {
                        let res = (|| -> crate::Result<u64> {
                            if let Some(first) = push_errors.remove(&matrix_id) {
                                anyhow::bail!("push stream had failures: {first}");
                            }
                            let block = shared.store.get(matrix_id)?;
                            check_session(block.session, conn_session, matrix_id)?;
                            Ok(block.rows_received())
                        })();
                        match res {
                            Ok(rows_received) => {
                                Action::Reply(DataMsg::PushDoneAck { matrix_id, rows_received })
                            }
                            Err(e) => {
                                Action::Reply(DataMsg::DataError { message: e.to_string() })
                            }
                        }
                    }
                    DataMsg::PullRows { matrix_id, start_row, nrows, start_col, sel_cols } => {
                        Action::ServePull { matrix_id, start_row, nrows, start_col, sel_cols }
                    }
                    DataMsg::DataBye => Action::Close,
                    other => Action::Reply(DataMsg::DataError {
                        message: format!("unexpected message on data socket: {other:?}"),
                    }),
                },
            }
        };
        match action {
            Action::Nothing => {}
            Action::Close => return,
            Action::Reply(reply) => {
                if framed.send_data_flush(&reply).is_err() {
                    return;
                }
            }
            Action::ServePull { matrix_id, start_row, nrows, start_col, sel_cols } => {
                if serve_pull(
                    shared,
                    &mut framed,
                    conn_session,
                    matrix_id,
                    start_row,
                    nrows,
                    start_col,
                    sel_cols,
                    frame_rows,
                )
                .is_err()
                {
                    return;
                }
            }
        }
    }
}

/// Driver-side helper: allocate a matrix for ingest across one session's
/// worker group. `ranks[slot]` is the global rank filling layout slot
/// `slot` (the session's group-local rank).
pub fn alloc_group(
    workers: &[Arc<WorkerShared>],
    ranks: &[usize],
    session_id: u64,
    id: u64,
    name: &str,
    layout: &RowBlockLayout,
) -> crate::Result<()> {
    for (slot, &rank) in ranks.iter().enumerate() {
        workers[rank].store.alloc(id, name, layout.clone(), slot, session_id)?;
    }
    Ok(())
}

/// Driver-side helper for v7 `LoadMatrix`: register an `hdf5sim` file as
/// a matrix across one session's worker group without any client-side
/// payload traffic. Preferred path: `mmap` the file once per process and
/// register each worker's row range as a mapped block (zero heap bytes,
/// budget-exempt — the page cache IS the storage). Hosts where the
/// in-place mapping is unavailable (non-unix, big-endian) fall back to
/// buffered per-shard reads into ordinary heap blocks, which stay
/// subject to the session budget. All-or-nothing: a failure on any rank
/// rolls back the ranks already registered.
pub fn load_group(
    workers: &[Arc<WorkerShared>],
    ranks: &[usize],
    session_id: u64,
    id: u64,
    name: &str,
    path: &std::path::Path,
    layout: &RowBlockLayout,
) -> crate::Result<()> {
    let result = (|| -> crate::Result<()> {
        match crate::hdf5sim::MappedMatrix::open(path) {
            Ok(map) => {
                let map = Arc::new(map);
                for (slot, &rank) in ranks.iter().enumerate() {
                    workers[rank].store.insert_mapped(
                        id,
                        name,
                        layout.clone(),
                        map.clone(),
                        slot,
                        session_id,
                    )?;
                }
            }
            Err(e) => {
                log::info!("mmap ingest unavailable for {path:?} ({e}); buffered load");
                for (slot, &rank) in ranks.iter().enumerate() {
                    let (lo, hi) = layout.ranges[slot];
                    let local = crate::hdf5sim::read_rows(path, lo, hi)?;
                    workers[rank].store.insert(
                        id,
                        name,
                        layout.clone(),
                        local,
                        slot,
                        session_id,
                    )?;
                }
            }
        }
        Ok(())
    })();
    if result.is_err() {
        for &rank in ranks {
            workers[rank].store.free(id);
        }
    }
    result
}
