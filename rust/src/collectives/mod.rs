//! The MPI stand-in (DESIGN.md §2).
//!
//! Alchemist's workers are MPI ranks; this module gives the rust workers
//! the same programming model: a [`Communicator`] with point-to-point
//! send/recv plus the collective algorithms the numerics need (barrier,
//! binomial-tree broadcast/reduce, ring allreduce, gather/scatter/
//! allgather). The collectives are implemented *over* send/recv — the real
//! algorithms, not shared-memory shortcuts — so their communication volume
//! is faithful and the SimClock can charge modeled interconnect time per
//! message (the box has one core; see `metrics::simclock`).
//!
//! Groups come in two flavors: [`LocalComm::group`] builds the full pool,
//! and [`LocalComm::subgroup`] builds an independent communicator over an
//! arbitrary rank subset — the substrate for session-scoped worker groups
//! (disjoint sessions collect over disjoint fabrics, so they never
//! serialize on each other).
//!
//! **Failure propagation (protocol v5).** Collectives are *fallible*: a
//! rank that panics, errors, or is hard-cancelled cannot contribute to its
//! peers' collectives, and without intervention those peers would block in
//! an allreduce forever (the availability bug the Cray deployment
//! follow-up calls out). The fix is group *poisoning*: when a rank fails,
//! its worker loop calls [`Communicator::poison`] with the failed rank,
//! and every peer blocked in — or later entering — `recv`/`barrier` wakes
//! immediately with [`CommError::PeerFailed`] instead of waiting for a
//! contribution that will never come. The [`algorithms`] are all
//! `Result`-returning and propagate the first failure; the
//! [`algorithms::infallible`] wrappers exist for callers whose groups can
//! never be poisoned (single-rank groups, direct library use, benches).

//!
//! **Transports (protocol v8).** Two implementations exist:
//! [`LocalComm`] (threads in one process, lock-free mailboxes) and
//! [`netcomm::TcpComm`] (worker ranks as separate OS processes joined by
//! a coordinator-brokered TCP mesh — see `docs/fabric.md`). The
//! [`Fabric`] trait is the server-side superset the dispatcher manages:
//! a `Communicator` that can also be `reset` between tasks.

pub mod algorithms;
pub mod local;
pub mod netcomm;

pub use algorithms::{
    allgather, allreduce_sum, broadcast, gather, reduce_sum, scatter,
};
pub use local::LocalComm;
pub use netcomm::{loopback_group, FabricOptions, MeshAcceptor, TcpComm};

/// Why a collective operation failed. Only the coordinator's fault
/// machinery produces these: outside it (direct library use, tests) the
/// fallible collectives cannot fail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommError {
    /// The group is poisoned because group-local `rank` failed (panicked
    /// or returned an error) while its peers were — or were about to be —
    /// blocked in a collective. Errors carrying this variant are
    /// *collateral*: the named rank is the root cause, not the rank that
    /// observed the error.
    PeerFailed { rank: usize },
    /// The group was poisoned by a hard cancel (a `CancelTask
    /// { hard_after_ms }` escalation or forced session teardown), not by
    /// a rank failure.
    Cancelled,
    /// [`Communicator::recv_deadline`] elapsed without a matching
    /// message; the group is *not* poisoned.
    Timeout { from: usize, tag: u64 },
}

impl CommError {
    /// Whether this error is *collateral* — the observing rank unwound
    /// because the group was already poisoned, rather than failing on its
    /// own. Both the worker loop (to avoid re-poisoning over the root
    /// cause) and the dispatcher's failure aggregation (to report the
    /// root cause, not its blast radius) classify through this one
    /// predicate so they can never disagree. `Timeout` is a local
    /// failure, not collateral.
    pub fn is_collateral(&self) -> bool {
        matches!(self, CommError::PeerFailed { .. } | CommError::Cancelled)
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::PeerFailed { rank } => {
                write!(f, "collective aborted: peer rank {rank} failed")
            }
            CommError::Cancelled => {
                write!(f, "collective aborted: task hard-cancelled")
            }
            CommError::Timeout { from, tag } => {
                write!(f, "recv deadline expired waiting for rank {from} (tag {tag})")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// What poisoned a group (see [`Communicator::poison`]). Stored once per
/// fabric; the first poisoner wins, so the recorded cause is the *root*
/// cause even when collateral failures cascade afterwards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoisonCause {
    /// Group-local rank that failed on its own (panic or error).
    RankFailed(usize),
    /// Deadline escalation / teardown: no rank failed, the driver pulled
    /// the plug.
    HardCancel,
}

impl PoisonCause {
    /// The error every blocked/arriving rank observes for this poison.
    pub fn to_err(self) -> CommError {
        match self {
            PoisonCause::RankFailed(rank) => CommError::PeerFailed { rank },
            PoisonCause::HardCancel => CommError::Cancelled,
        }
    }
}

/// Point-to-point message transport between ranks of one worker group.
///
/// Messages are `Vec<f64>` (every payload in this system is double
/// precision) addressed by `(peer, tag)`; tags keep concurrent collectives
/// from interleaving. Implementations must deliver messages from the same
/// (sender, tag) in order.
///
/// Receive paths and the barrier are fallible: once the group is poisoned
/// (see [`Communicator::poison`]) every blocked or arriving rank observes
/// the poison as a [`CommError`] instead of blocking forever. `send` stays
/// infallible — it is buffered and never blocks, and a send into a
/// poisoned group is simply never received.
pub trait Communicator: Send {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;
    /// Non-blocking buffered send.
    fn send(&self, to: usize, tag: u64, data: Vec<f64>);
    /// Blocking receive; wakes with the poison error if the group is (or
    /// becomes) poisoned.
    fn recv(&self, from: usize, tag: u64) -> Result<Vec<f64>, CommError>;
    /// [`Communicator::recv`] with a deadline: returns
    /// [`CommError::Timeout`] if no matching message arrives within
    /// `timeout` (poison still wins over the timeout).
    fn recv_deadline(
        &self,
        from: usize,
        tag: u64,
        timeout: std::time::Duration,
    ) -> Result<Vec<f64>, CommError>;
    /// Block until every rank arrives — or the group is poisoned, in
    /// which case every waiter (and every later arriver) errors instead.
    fn barrier(&self) -> Result<(), CommError>;
    /// Poison the whole group: every rank blocked in (or later calling)
    /// `recv`/`recv_deadline`/`barrier` errors with `cause`'s
    /// [`CommError`]. Idempotent; the first cause is kept (it is the root
    /// cause — later poisons are collateral).
    fn poison(&self, cause: PoisonCause);
    /// The group's current poison, if any.
    fn poison_cause(&self) -> Option<PoisonCause>;
    /// Poison ONE tag lane (protocol v9, `docs/scheduler.md`): every rank
    /// blocked in — or later calling — `recv`/`recv_deadline` on a tag in
    /// `lane`'s window errors with `cause`, while traffic in other lanes
    /// keeps flowing (concurrent tasks on one group must not share fate).
    /// Transports without lane bookkeeping fall back to poisoning the
    /// whole group, which is always safe, just coarser.
    fn poison_lane(&self, _lane: u64, cause: PoisonCause) {
        self.poison(cause);
    }
    /// The poison governing `lane`: the group-wide cause if any (a rank
    /// failure fails every lane), else the lane's own.
    fn lane_poison_cause(&self, _lane: u64) -> Option<PoisonCause> {
        self.poison_cause()
    }
    /// Modeled communication seconds charged to this rank so far (for
    /// simulated-cluster-time accounting); implementations without a cost
    /// model return 0.
    fn sim_comm_secs(&self) -> f64 {
        0.0
    }
}

/// Tag-space layout so nested collectives never collide: each collective
/// invocation passes a distinct `base` tag and algorithms offset within
/// a 2^16 window. The [`algorithms`] debug-assert both halves of the
/// contract: `base` must be `TAG_WINDOW`-aligned and every per-algorithm
/// offset must stay inside the window.
pub const TAG_WINDOW: u64 = 1 << 16;

/// Tag-lane layout (protocol v9, `docs/scheduler.md`): concurrent tasks
/// on ONE group communicator each own the disjoint tag window
/// `[lane << LANE_SHIFT, (lane + 1) << LANE_SHIFT)`. Every routine's
/// absolute base tag is a 32-bit constant, so offsetting by `lane << 32`
/// keeps bases `TAG_WINDOW`-aligned (the [`algorithms`] contract) while
/// two tasks' traffic can never collide. Lane 0 is direct/untasked use
/// (benches, subgroup helpers — the pre-v9 tag space, unchanged on the
/// wire); tasks get lanes ≥ 1, assigned from a monotonic per-session
/// counter and never reused, so a finished task's stragglers land in a
/// window nobody will ever read again.
pub const LANE_SHIFT: u32 = 32;

/// First tag of `lane`'s window.
pub const fn lane_base(lane: u64) -> u64 {
    lane << LANE_SHIFT
}

/// Which lane a data tag belongs to. Tags with the transport-private
/// barrier bit (bit 63, `netcomm`) are group-wide control traffic and
/// map to lane 0.
pub const fn lane_of_tag(tag: u64) -> u64 {
    if tag & (1 << 63) != 0 {
        0
    } else {
        tag >> LANE_SHIFT
    }
}

/// Lane value meaning "the whole group, every lane" in wire messages
/// that carry a lane field (`WorkMsg::MeshPoison`, `FabricFrame::Poison`).
pub const LANE_ALL: u64 = u64::MAX;

/// A [`Communicator`] as the server's dispatcher manages it: collectives
/// during a task, plus a `reset` between tasks that drops stragglers and
/// clears poison so the next task starts on a clean fabric. Both
/// transports implement it; sessions hold `Arc<dyn Fabric>` so a worker
/// loop cannot tell (and must not care) which transport its group is on.
pub trait Fabric: Communicator + Send + Sync {
    /// Clear all transient group state between tasks (queued messages,
    /// poison, barrier generations).
    fn reset(&self);
    /// Retire ONE task's tag lane (protocol v9): drop its queued and
    /// in-flight messages and clear its lane poison, without touching
    /// sibling lanes — the per-task counterpart of [`Fabric::reset`],
    /// which stays the whole-group recovery path (rank failure, session
    /// teardown). Lanes are never reassigned, so retirement is garbage
    /// collection, not reuse hygiene.
    fn retire_lane(&self, _lane: u64) {}
    /// This fabric as a plain [`Communicator`] — the view handed to
    /// library routines. (Explicit because trait-object upcasting is
    /// newer than this crate's compiler floor.)
    fn as_comm(&self) -> &dyn Communicator;
}

/// One task's view of a group communicator (protocol v9): every tag is
/// offset into the task's lane window, so concurrent tasks over the SAME
/// `Fabric` use disjoint tag spaces and the routines — whose base tags
/// are absolute 32-bit constants — need no changes at all. The barrier is
/// a dissemination barrier over lane-tagged messages (the transport's
/// group-wide barrier would rendezvous *tasks*, not ranks); poison is
/// scoped to the lane, so hard-cancelling one task wakes only its own
/// ranks while a sibling task's collectives keep flowing.
pub struct LaneComm {
    inner: std::sync::Arc<dyn Fabric>,
    lane: u64,
    base: u64,
    /// Dissemination-barrier generation, local to this endpoint. Masked
    /// to 16 bits in the tag: ranks skew by at most one generation (you
    /// cannot finish barrier g+1 before receiving messages only sent by
    /// peers that finished g), so wraparound can never collide.
    barrier_gen: std::sync::atomic::AtomicU64,
}

/// Offset of barrier traffic inside a lane window: above every routine's
/// base-tag constant (all < `0xFF00_0000`), below the window end. Layout:
/// `0xFF00_0000 | (generation & 0xFFFF) << 8 | round`.
const LANE_BARRIER_OFF: u64 = 0xFF00_0000;

impl LaneComm {
    /// Wrap `inner` so every tag lands in `lane`'s window. `lane` must be
    /// ≥ 1 (lane 0 is the untasked tag space) and small enough that the
    /// window stays clear of the transport barrier bit.
    pub fn new(inner: std::sync::Arc<dyn Fabric>, lane: u64) -> Self {
        debug_assert!(lane >= 1 && lane < (1 << 30), "lane {lane} out of range");
        LaneComm {
            inner,
            lane,
            base: lane_base(lane),
            barrier_gen: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub fn lane(&self) -> u64 {
        self.lane
    }

    /// The wrapped group fabric (driver-side plumbing; routines only ever
    /// see the [`Communicator`] view).
    pub fn fabric(&self) -> &std::sync::Arc<dyn Fabric> {
        &self.inner
    }
}

impl Communicator for LaneComm {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send(&self, to: usize, tag: u64, data: Vec<f64>) {
        debug_assert!(tag < lane_base(1), "tag {tag:#x} escapes the lane window");
        self.inner.send(to, self.base + tag, data);
    }

    fn recv(&self, from: usize, tag: u64) -> Result<Vec<f64>, CommError> {
        self.inner.recv(from, self.base + tag)
    }

    fn recv_deadline(
        &self,
        from: usize,
        tag: u64,
        timeout: std::time::Duration,
    ) -> Result<Vec<f64>, CommError> {
        self.inner.recv_deadline(from, self.base + tag, timeout)
    }

    fn barrier(&self) -> Result<(), CommError> {
        use std::sync::atomic::Ordering;
        if let Some(cause) = self.inner.lane_poison_cause(self.lane) {
            return Err(cause.to_err());
        }
        let size = self.size();
        if size <= 1 {
            return Ok(());
        }
        let rank = self.rank();
        let gen = self.barrier_gen.fetch_add(1, Ordering::Relaxed);
        let tag_for = |round: u64| {
            self.base + (LANE_BARRIER_OFF | ((gen & 0xFFFF) << 8) | round)
        };
        let mut distance = 1usize;
        let mut round = 0u64;
        while distance < size {
            let to = (rank + distance) % size;
            let from = (rank + size - distance) % size;
            self.inner.send(to, tag_for(round), Vec::new());
            self.inner.recv(from, tag_for(round))?;
            distance *= 2;
            round += 1;
        }
        Ok(())
    }

    fn poison(&self, cause: PoisonCause) {
        self.inner.poison_lane(self.lane, cause);
    }

    fn poison_cause(&self) -> Option<PoisonCause> {
        self.inner.lane_poison_cause(self.lane)
    }

    fn poison_lane(&self, _lane: u64, cause: PoisonCause) {
        // lanes don't nest: a task's "whole group" IS its lane
        self.inner.poison_lane(self.lane, cause);
    }

    fn lane_poison_cause(&self, _lane: u64) -> Option<PoisonCause> {
        self.inner.lane_poison_cause(self.lane)
    }

    fn sim_comm_secs(&self) -> f64 {
        self.inner.sim_comm_secs()
    }
}
