//! Integration: the PJRT runtime + the XLA/Pallas engines against the
//! native oracle. Requires `make artifacts` (skips loudly otherwise).

use alchemist::collectives::Communicator;
use alchemist::compute::{Engine, GemmVariant, NativeEngine, XlaEngine};
use alchemist::config::Config;
use alchemist::distmat::LocalMatrix;
use alchemist::runtime::Runtime;
use alchemist::util::prng::Rng;

fn artifacts_available(cfg: &Config) -> bool {
    cfg.resolved_artifacts_dir().join("manifest.txt").exists()
}

fn cfg() -> Config {
    Config::default()
}

macro_rules! require_artifacts {
    ($cfg:expr) => {
        if !artifacts_available(&$cfg) {
            eprintln!("SKIP: artifacts missing; run `make artifacts`");
            return;
        }
    };
}

fn random(seed: u64, r: usize, c: usize) -> LocalMatrix {
    let mut rng = Rng::new(seed);
    LocalMatrix::from_fn(r, c, |_, _| rng.normal())
}

#[test]
fn manifest_loads_and_gemm_artifact_runs() {
    let cfg = cfg();
    require_artifacts!(cfg);
    let mut rt = Runtime::load(&cfg.resolved_artifacts_dir()).unwrap();
    assert!(rt.manifest().entries().len() >= 20);

    // run the xla gemm tile directly: c + a@b at 256
    let t = 256usize;
    let c = vec![1.0; t * t];
    let a = vec![0.5; t * t];
    let b = vec![2.0; t * t];
    let shape = [t, t];
    let out = rt
        .run1(
            "xla_gemm_nn_256x256x256",
            &[(&c, shape.as_slice()), (&a, shape.as_slice()), (&b, shape.as_slice())],
        )
        .unwrap();
    // each element: 1 + sum_k 0.5*2 = 1 + 256
    assert!((out.data[0] - 257.0).abs() < 1e-9);
    assert!((out.data[t * t - 1] - 257.0).abs() < 1e-9);
    assert_eq!(rt.exec_calls, 1);
    assert!(rt.exec_secs > 0.0);
}

#[test]
fn pallas_artifact_matches_xla_artifact() {
    let cfg = cfg();
    require_artifacts!(cfg);
    let mut rt = Runtime::load(&cfg.resolved_artifacts_dir()).unwrap();
    let t = 256usize;
    let c = random(1, t, t);
    let a = random(2, t, t);
    let b = random(3, t, t);
    let shape = [t, t];
    let inputs = [
        (c.data(), shape.as_slice()),
        (a.data(), shape.as_slice()),
        (b.data(), shape.as_slice()),
    ];
    let x = rt.run1("xla_gemm_nn_256x256x256", &inputs).unwrap();
    let p = rt.run1("pallas_gemm_nn_256x256x256", &inputs).unwrap();
    let max_diff = x
        .data
        .iter()
        .zip(&p.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(max_diff < 1e-9, "pallas vs xla: {max_diff}");
}

fn check_engine_against_native(family: &'static str) {
    let mut cfg = cfg();
    require_artifacts!(cfg);
    cfg.engine = if family == "pallas" {
        alchemist::config::EngineKind::Pallas
    } else {
        alchemist::config::EngineKind::Xla
    };
    let mut xla = XlaEngine::new(&cfg, family).unwrap();
    let mut native = NativeEngine::new();

    // GEMM with padding in every dimension (note: tile is 256)
    for &(variant, m, n, k) in &[
        (GemmVariant::NN, 300usize, 130usize, 70usize),
        (GemmVariant::TN, 64, 40, 500),
        (GemmVariant::NT, 256, 256, 256),
    ] {
        let a_shape = match variant {
            GemmVariant::TN => (k, m),
            _ => (m, k),
        };
        let b_shape = match variant {
            GemmVariant::NT => (n, k),
            _ => (k, n),
        };
        let a = random(10, a_shape.0, a_shape.1);
        let b = random(11, b_shape.0, b_shape.1);
        let seed_c = random(12, m, n);
        let mut c1 = seed_c.clone();
        xla.gemm(variant, &mut c1, &a, &b).unwrap();
        let mut c2 = seed_c.clone();
        native.gemm(variant, &mut c2, &a, &b).unwrap();
        let d = c1.max_abs_diff(&c2);
        assert!(d < 1e-8, "{family} gemm {variant:?} {m}x{n}x{k}: {d}");
    }

    // gram_matvec through the fused panel artifact (k=1024, c=32 exists;
    // k=700, c=5 forces padding)
    let a = random(13, 100, 700);
    let v = random(14, 700, 5);
    let g1 = xla.gram_matvec(&a, &v, 0.25).unwrap();
    let g2 = native.gram_matvec(&a, &v, 0.25).unwrap();
    assert!(g1.max_abs_diff(&g2) < 1e-7, "{family} gram: {}", g1.max_abs_diff(&g2));

    // rff_expand (k0=300 pads to 512; d=1500 chunks at 1024)
    let x = random(15, 90, 300);
    let omega = random(16, 300, 1500);
    let bias: Vec<f64> = random(17, 1, 1500).into_data();
    let z1 = xla.rff_expand(&x, &omega, &bias, 0.05).unwrap();
    let z2 = native.rff_expand(&x, &omega, &bias, 0.05).unwrap();
    assert!(z1.max_abs_diff(&z2) < 1e-9, "{family} rff: {}", z1.max_abs_diff(&z2));

    // cg_update (rows 1500 chunks at 1024; cols 7 pads to 32)
    let p = random(18, 1500, 7);
    let q = random(19, 1500, 7);
    let alpha: Vec<f64> = random(20, 1, 7).into_data();
    let (mut x1, mut r1) = (random(21, 1500, 7), random(22, 1500, 7));
    let (mut x2, mut r2) = (x1.clone(), r1.clone());
    xla.cg_update(&mut x1, &mut r1, &p, &q, &alpha).unwrap();
    native.cg_update(&mut x2, &mut r2, &p, &q, &alpha).unwrap();
    assert!(x1.max_abs_diff(&x2) < 1e-12 && r1.max_abs_diff(&r2) < 1e-12);

    let (calls, secs) = xla.exec_stats();
    assert!(calls > 0 && secs > 0.0, "{family} engine must have hit PJRT");
}

#[test]
fn xla_engine_matches_native() {
    check_engine_against_native("xla");
}

#[test]
fn keyed_gram_cache_is_correct_and_isolated() {
    // The §Perf operand cache must (a) return bit-identical results to the
    // uncached path across repeated calls, and (b) never alias between
    // different keys even when matrices share shapes.
    let cfg = cfg();
    require_artifacts!(cfg);
    let mut engine = XlaEngine::new(&cfg, "xla").unwrap();
    let mut native = NativeEngine::new();

    for trial in 0..4u64 {
        let rows = [100usize, 1024, 2048, 3000][trial as usize % 4];
        let k = [700usize, 1024, 512, 2048][trial as usize % 4];
        let c = [5usize, 32, 1, 8][trial as usize % 4];
        let a = random(100 + trial, rows, k);
        let b = random(200 + trial, rows, k); // same shape, different data
        let key_a = alchemist::compute::fresh_operand_key();
        let key_b = alchemist::compute::fresh_operand_key();
        for it in 0..3 {
            let v = random(300 + trial * 10 + it, k, c);
            let ga = engine.gram_matvec_keyed(key_a, &a, &v, 0.3).unwrap();
            let gb = engine.gram_matvec_keyed(key_b, &b, &v, 0.3).unwrap();
            let wa = native.gram_matvec(&a, &v, 0.3).unwrap();
            let wb = native.gram_matvec(&b, &v, 0.3).unwrap();
            assert!(ga.max_abs_diff(&wa) < 1e-7, "trial {trial} it {it} key_a");
            assert!(gb.max_abs_diff(&wb) < 1e-7, "trial {trial} it {it} key_b");
            // a != b, so cached panels must differ too
            assert!(ga.max_abs_diff(&gb) > 1e-6, "keys must not alias");
        }
    }
}

#[test]
fn pallas_engine_matches_native() {
    check_engine_against_native("pallas");
}

#[test]
fn distributed_cg_on_xla_engine() {
    let cfg = cfg();
    require_artifacts!(cfg);
    // SPMD CG where every rank uses its own XlaEngine (the production
    // configuration of the speech experiment)
    let n = 120usize;
    let x = random(30, n, 24);
    let y = random(31, n, 3);
    let opts = alchemist::linalg::CgOptions { lambda: 1e-3, tol: 1e-11, max_iters: 200 };

    let want = {
        let comms = alchemist::collectives::LocalComm::group(1, None);
        alchemist::linalg::cg_solve(
            &comms[0],
            &mut NativeEngine::new(),
            &x,
            &y,
            n,
            &opts,
        )
        .unwrap()
    };

    let layout = alchemist::distmat::RowBlockLayout::even(n, 24, 2);
    let comms = alchemist::collectives::LocalComm::group(2, None);
    let mut handles = Vec::new();
    for comm in comms {
        let (a, b) = layout.ranges[comm.rank()];
        let xl = x.slice_rows(a, b);
        let yl = y.slice_rows(a, b);
        let cfg = cfg.clone();
        let opts = opts.clone();
        handles.push(std::thread::spawn(move || {
            let mut engine = XlaEngine::new(&cfg, "xla").unwrap();
            alchemist::linalg::cg_solve(&comm, &mut engine, &xl, &yl, n, &opts).unwrap()
        }));
    }
    for h in handles {
        let got = h.join().unwrap();
        assert!(
            got.w.max_abs_diff(&want.w) < 1e-6,
            "diff {}",
            got.w.max_abs_diff(&want.w)
        );
    }
}
