//! Engine-equivalence suite: the parallel native engine must be
//! **bit-identical** to `threads = 1` for all four `Engine` ops, across
//! thread counts and edge shapes — the determinism contract that keeps
//! replicated SPMD solver state bitwise-equal across ranks
//! (`docs/compute.md`). Plus a `distributed_matches_serial`-style solver
//! run with the pool enabled.

use alchemist::collectives::LocalComm;
use alchemist::compute::{Engine, GemmVariant, NativeEngine};
use alchemist::distmat::dense::{GEMM_KC, GEMM_MC, GEMM_MR, GEMM_NR};
use alchemist::distmat::{LocalMatrix, RowBlockLayout};
use alchemist::linalg::{cg_solve, truncated_svd, CgOptions, SvdOptions, SvdResult};
use alchemist::util::prng::Rng;

fn random(rng: &mut Rng, r: usize, c: usize) -> LocalMatrix {
    LocalMatrix::from_fn(r, c, |_, _| rng.normal())
}

/// Edge shapes for the GEMM family: degenerate vectors, tall-skinny,
/// sizes straddling the micro-tile (MR×NR), panel (MC) and k-block (KC)
/// boundaries, and empty-k.
fn gemm_shapes() -> Vec<(usize, usize, usize)> {
    vec![
        (1, 1, 1),
        (1, 17, 5),                    // 1×n row
        (7, 1, 3),                     // n×1 column
        (200, 3, 64),                  // tall-skinny
        (GEMM_MR, GEMM_NR, 4),         // exactly one micro-tile
        (GEMM_MR + 1, GEMM_NR + 1, 5), // one past the micro-tile
        (GEMM_MC - 1, GEMM_NR * 2 + 3, GEMM_KC + 1), // straddles MC and KC
        (GEMM_MC * 2 + 1, 7, 33),      // several parallel panels
        (64, 8, 0),                    // empty-k: gemm is a no-op
    ]
}

#[test]
fn gemm_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(41);
    for (m, n, k) in gemm_shapes() {
        let a = random(&mut rng, m, k);
        let b = random(&mut rng, k, n);
        let at = a.transpose();
        let bt = b.transpose();
        let seed = random(&mut rng, m, n); // nonzero C: gemm accumulates
        for variant in [GemmVariant::NN, GemmVariant::TN, GemmVariant::NT] {
            let (opa, opb) = match variant {
                GemmVariant::NN => (&a, &b),
                GemmVariant::TN => (&at, &b),
                GemmVariant::NT => (&a, &bt),
            };
            let mut want = seed.clone();
            NativeEngine::with_threads(1).gemm(variant, &mut want, opa, opb).unwrap();
            for threads in [2usize, 4] {
                let mut got = seed.clone();
                NativeEngine::with_threads(threads).gemm(variant, &mut got, opa, opb).unwrap();
                assert_eq!(
                    got, want,
                    "{} {m}x{n}x{k} threads={threads}",
                    variant.op_name()
                );
            }
        }
    }
}

#[test]
fn fused_ops_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(42);
    // rows straddle the engine's 256-row chunk grain; cols straddle the
    // micro-tile widths
    for &(rows, d, nrhs) in &[
        (1usize, 5usize, 2usize),
        (255, 9, 1),
        (256, 16, 4),
        (257, 7, 3),
        (600, 37, 5),
        (1, 1, 1),
    ] {
        let a = random(&mut rng, rows, d);
        let v = random(&mut rng, d, nrhs);
        let want = NativeEngine::with_threads(1).gram_matvec(&a, &v, 0.9).unwrap();
        for threads in [2usize, 4] {
            let got = NativeEngine::with_threads(threads).gram_matvec(&a, &v, 0.9).unwrap();
            assert_eq!(got, want, "gram_matvec {rows}x{d}x{nrhs} t={threads}");
        }

        // cg_update: x/r mutated in place
        let x0 = random(&mut rng, rows, nrhs);
        let r0 = random(&mut rng, rows, nrhs);
        let p = random(&mut rng, rows, nrhs);
        let q = random(&mut rng, rows, nrhs);
        let alpha: Vec<f64> = (0..nrhs).map(|_| rng.normal()).collect();
        let (mut xw, mut rw) = (x0.clone(), r0.clone());
        NativeEngine::with_threads(1).cg_update(&mut xw, &mut rw, &p, &q, &alpha).unwrap();
        for threads in [2usize, 4] {
            let (mut xg, mut rg) = (x0.clone(), r0.clone());
            NativeEngine::with_threads(threads)
                .cg_update(&mut xg, &mut rg, &p, &q, &alpha)
                .unwrap();
            assert_eq!(xg, xw, "cg_update x {rows}x{nrhs} t={threads}");
            assert_eq!(rg, rw, "cg_update r {rows}x{nrhs} t={threads}");
        }

        // rff_expand: rows×d input through a d×(2d+1) map
        let omega = random(&mut rng, d, 2 * d + 1);
        let bias: Vec<f64> = (0..2 * d + 1).map(|_| rng.uniform_in(0.0, 6.28)).collect();
        let scale = (2.0f64 / (2 * d + 1) as f64).sqrt();
        let want = NativeEngine::with_threads(1).rff_expand(&a, &omega, &bias, scale).unwrap();
        for threads in [2usize, 4] {
            let got = NativeEngine::with_threads(threads)
                .rff_expand(&a, &omega, &bias, scale)
                .unwrap();
            assert_eq!(got, want, "rff_expand {rows}x{d} t={threads}");
        }
    }
}

#[test]
fn cg_solver_state_bit_identical_across_engine_threads() {
    // the whole iterative solve — not just one op — must be replay-equal
    // across pool sizes: every iterate feeds the next, so a single
    // reassociated reduction anywhere would diverge the trajectories
    let mut rng = Rng::new(43);
    let n = 300usize;
    let x = random(&mut rng, n, 12);
    let y = random(&mut rng, n, 3);
    let opts = CgOptions { lambda: 1e-3, tol: 1e-10, max_iters: 200 };
    let comms = LocalComm::group(1, None);
    let base = cg_solve(&comms[0], &mut NativeEngine::with_threads(1), &x, &y, n, &opts).unwrap();
    for threads in [2usize, 4] {
        let comms = LocalComm::group(1, None);
        let got = cg_solve(&comms[0], &mut NativeEngine::with_threads(threads), &x, &y, n, &opts)
            .unwrap();
        assert_eq!(got.w, base.w, "threads={threads}");
        assert_eq!(got.iters, base.iters, "threads={threads}");
        assert_eq!(got.residuals, base.residuals, "threads={threads}");
    }
}

/// `distributed_matches_serial` with the pool enabled: pooled engines on
/// every rank must keep (a) the replicated SPMD state bitwise-equal
/// across ranks, (b) the whole distributed result bit-identical to the
/// same distributed run at `threads = 1`, and (c) the spectrum close to
/// the serial single-rank solve.
#[test]
fn distributed_svd_matches_serial_with_pool_enabled() {
    let mut rng = Rng::new(44);
    let n = 320usize;
    let k_dim = 24usize;
    let a = random(&mut rng, n, k_dim);
    let opts = SvdOptions { rank: 3, steps: 0, seed: 2 };

    let serial = {
        let comms = LocalComm::group(1, None);
        truncated_svd(&comms[0], &mut NativeEngine::with_threads(1), &a, &opts).unwrap()
    };

    let run_distributed = |workers: usize, threads: usize| -> Vec<SvdResult> {
        let layout = RowBlockLayout::even(n, k_dim, workers);
        let comms = LocalComm::group(workers, None);
        let mut handles = Vec::new();
        for comm in comms {
            let (ra, rb) = layout.ranges[comm.rank()];
            let local = a.slice_rows(ra, rb);
            let opts = opts.clone();
            handles.push(std::thread::spawn(move || {
                truncated_svd(
                    &comm,
                    &mut NativeEngine::with_threads(threads),
                    &local,
                    &opts,
                )
                .unwrap()
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    };

    for workers in [2usize, 3] {
        let base = run_distributed(workers, 1);
        let pooled = run_distributed(workers, 2);
        for (rank, res) in pooled.iter().enumerate() {
            // (a) replicated state identical across ranks
            assert_eq!(res.v, pooled[0].v, "workers={workers} rank={rank}");
            assert_eq!(res.sigma, pooled[0].sigma, "workers={workers} rank={rank}");
            // (b) pool-invariance of the full distributed run
            assert_eq!(res.v, base[rank].v, "workers={workers} rank={rank}");
            assert_eq!(res.sigma, base[rank].sigma, "workers={workers} rank={rank}");
            assert_eq!(
                res.u_local.data(),
                base[rank].u_local.data(),
                "workers={workers} rank={rank}"
            );
            // (c) correct spectrum vs the serial solve
            for (g, w) in res.sigma.iter().zip(&serial.sigma) {
                assert!((g - w).abs() < 1e-6, "workers={workers}: {g} vs {w}");
            }
        }
    }
}
