//! Random Fourier features (Rahimi–Recht) — the expansion the paper
//! applies to TIMIT *inside Alchemist* (§4.1: shipping the raw 440-feature
//! matrix and expanding server-side is far cheaper than transferring the
//! expanded multi-TB matrix).
//!
//! For a Gaussian kernel of bandwidth γ: `z(x) = √(2/D)·cos(x·Ω + b)` with
//! `Ω ~ N(0, γ²)` and `b ~ U[0, 2π)`. The map is generated deterministically
//! from a seed so every worker rank (and the test oracle) materializes the
//! identical Ω, b without communication.

use crate::compute::Engine;
use crate::distmat::LocalMatrix;
use crate::util::prng::Rng;

/// A materialized random-feature map `k0 → d`.
pub struct RffMap {
    pub omega: LocalMatrix,
    pub bias: Vec<f64>,
    pub scale: f64,
}

impl RffMap {
    /// Deterministically generate the map (same seed ⇒ same map on every
    /// rank).
    pub fn generate(k0: usize, d: usize, gamma: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5246_4600);
        let omega = LocalMatrix::from_fn(k0, d, |_, _| gamma * rng.normal());
        let bias: Vec<f64> =
            (0..d).map(|_| rng.uniform_in(0.0, 2.0 * std::f64::consts::PI)).collect();
        RffMap { omega, bias, scale: (2.0 / d as f64).sqrt() }
    }

    pub fn input_dim(&self) -> usize {
        self.omega.rows()
    }

    pub fn output_dim(&self) -> usize {
        self.omega.cols()
    }

    /// Expand a row-panel through the engine.
    pub fn expand(&self, engine: &mut dyn Engine, x: &LocalMatrix) -> crate::Result<LocalMatrix> {
        engine.rff_expand(x, &self.omega, &self.bias, self.scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::NativeEngine;

    #[test]
    fn deterministic_across_calls() {
        let a = RffMap::generate(4, 16, 0.5, 7);
        let b = RffMap::generate(4, 16, 0.5, 7);
        assert_eq!(a.omega, b.omega);
        assert_eq!(a.bias, b.bias);
        let c = RffMap::generate(4, 16, 0.5, 8);
        assert_ne!(c.omega, a.omega);
    }

    #[test]
    fn kernel_approximation_improves_with_d() {
        // z(x)ᵀz(y) ≈ exp(−γ²‖x−y‖²/2) for the Gaussian kernel with the
        // N(0, γ²) spectral measure.
        let gamma = 0.8;
        let mut rng = Rng::new(3);
        let x = LocalMatrix::from_fn(2, 6, |_, _| rng.normal());
        let dist2: f64 = (0..6)
            .map(|j| (x.get(0, j) - x.get(1, j)).powi(2))
            .sum();
        let want = (-gamma * gamma * dist2 / 2.0).exp();
        let mut errs = Vec::new();
        for d in [64usize, 4096] {
            let map = RffMap::generate(6, d, gamma, 11);
            let z = map.expand(&mut NativeEngine::new(), &x).unwrap();
            let got: f64 = (0..d).map(|j| z.get(0, j) * z.get(1, j)).sum();
            errs.push((got - want).abs());
        }
        assert!(errs[1] < errs[0], "kernel error should shrink: {errs:?}");
        assert!(errs[1] < 0.05, "kernel error too large: {errs:?}");
    }
}
