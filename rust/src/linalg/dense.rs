//! Small dense factorizations (replicated on every rank): Cholesky,
//! triangular solves, inverse via back-substitution. Used by CholeskyQR2
//! and the SVD driver; sizes here are k×k with k ≲ a few hundred.

use crate::distmat::LocalMatrix;

/// Cholesky factorization `a = lᵀ·l` with `l` upper-triangular (returns
/// `R` such that `a = Rᵀ R`). Errors on non-SPD input.
pub fn cholesky_upper(a: &LocalMatrix) -> crate::Result<LocalMatrix> {
    let n = a.rows();
    anyhow::ensure!(a.cols() == n, "cholesky needs a square matrix");
    let mut r = LocalMatrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let mut s = a.get(i, j);
            for k in 0..i {
                s -= r.get(k, i) * r.get(k, j);
            }
            if i == j {
                // relative pivot threshold: near-singular Gram matrices
                // (rank-deficient inputs) must fail loudly, not produce a
                // garbage factor
                let floor = 1e-12 * a.get(i, i).abs().max(1e-300);
                anyhow::ensure!(
                    s > floor,
                    "matrix not positive definite at pivot {i} (s = {s:.3e})"
                );
                r.set(i, j, s.sqrt());
            } else {
                r.set(i, j, s / r.get(i, i));
            }
        }
    }
    Ok(r)
}

/// Solve `x · r = b` for `x` where `r` is upper-triangular (right-solve;
/// used for `Q = A·R⁻¹`). `b` is m×n, `r` is n×n.
pub fn solve_right_upper(b: &LocalMatrix, r: &LocalMatrix) -> crate::Result<LocalMatrix> {
    let n = r.rows();
    anyhow::ensure!(r.cols() == n && b.cols() == n, "solve_right_upper shapes");
    let mut x = b.clone();
    for i in 0..b.rows() {
        let row = x.row_mut(i);
        for j in 0..n {
            let mut s = row[j];
            for k in 0..j {
                s -= row[k] * r.get(k, j);
            }
            let d = r.get(j, j);
            anyhow::ensure!(d != 0.0, "singular triangular factor at {j}");
            row[j] = s / d;
        }
    }
    Ok(x)
}

/// `a · b` convenience (native; these are replicated k×k products).
pub fn matmul(a: &LocalMatrix, b: &LocalMatrix) -> LocalMatrix {
    let mut c = LocalMatrix::zeros(a.rows(), b.cols());
    c.gemm_nn(a, b);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> LocalMatrix {
        let a = LocalMatrix::from_fn(n, n, |_, _| rng.normal());
        let mut g = LocalMatrix::identity(n); // + I keeps it well-conditioned
        g.gemm_tn(&a, &a);
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1usize, 2, 5, 20] {
            let g = spd(&mut rng, n);
            let r = cholesky_upper(&g).unwrap();
            // check Rᵀ R == G and upper-triangularity
            let mut rtr = LocalMatrix::zeros(n, n);
            rtr.gemm_tn(&r, &r);
            assert!(rtr.max_abs_diff(&g) < 1e-8 * n as f64);
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(r.get(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = LocalMatrix::from_data(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(cholesky_upper(&a).is_err());
    }

    #[test]
    fn right_solve_inverts() {
        let mut rng = Rng::new(2);
        let g = spd(&mut rng, 6);
        let r = cholesky_upper(&g).unwrap();
        let b = LocalMatrix::from_fn(4, 6, |_, _| rng.normal());
        let x = solve_right_upper(&b, &r).unwrap();
        let back = matmul(&x, &r);
        assert!(back.max_abs_diff(&b) < 1e-9);
    }
}
