//! XLA-backed engine: maps arbitrary shapes onto the fixed-shape AOT
//! artifacts by padding and tiling.
//!
//! HLO has static shapes, so `make artifacts` exports a small family of
//! shapes (square GEMM tiles, row-panel gram/rff/cg ops) and this engine
//! composes everything else:
//!
//! * GEMM — operands are pre-cut into `tile×tile` blocks (zero-padded at
//!   the edges); the K-loop threads the accumulator tile through repeated
//!   executions of the `gemm_{nn,tn,nt}_<T>` artifact.
//! * gram_matvec / rff_expand / cg_update — rows are chunked into panels
//!   of the artifact height, trailing dims padded to the nearest exported
//!   width, outputs shrunk back.
//!
//! When no panel artifact fits, the op falls back to GEMM-tile
//! composition — still entirely on the XLA path (never silently native).

use std::collections::HashMap;

use crate::config::{Config, EngineKind};
use crate::distmat::LocalMatrix;
use crate::runtime::{DeviceBuf, Runtime};
use crate::util::round_up;

use super::{Engine, GemmVariant};

/// Device-resident-operand cache cap; exceeded ⇒ cleared (operands are
/// re-uploadable at the cost of one copy).
const OPERAND_CACHE_CAP_BYTES: usize = 512 << 20;

pub struct XlaEngine {
    rt: Runtime,
    /// `"xla"` or `"pallas"` — which artifact family to resolve.
    family: &'static str,
    tile: usize,
    /// (operand key, panel index) → device-resident padded A panel.
    /// §Perf: keeps the static Gram panel on device across solver
    /// iterations instead of re-marshalling ~16 MB per call.
    operand_cache: HashMap<(u64, usize), DeviceBuf>,
    operand_cache_bytes: usize,
}

impl XlaEngine {
    pub fn new(cfg: &Config, family: &'static str) -> crate::Result<Self> {
        let rt = Runtime::load(&cfg.resolved_artifacts_dir())?;
        let tile = cfg.tile;
        anyhow::ensure!(
            rt.manifest().find("gemm_nn", family, &[tile, tile, tile]).is_some(),
            "no {family} gemm artifact for tile {tile} in manifest (run `make artifacts`)"
        );
        Ok(XlaEngine {
            rt,
            family,
            tile,
            operand_cache: HashMap::new(),
            operand_cache_bytes: 0,
        })
    }

    fn artifact(&self, op: &str, dims: &[usize]) -> Option<String> {
        self.rt
            .manifest()
            .find(op, self.family, dims)
            .map(|e| e.name.clone())
    }

    /// Smallest exported dims for `op` with `dims[fixed] == want[fixed]`
    /// for the given exact-match positions and `dims[i] >= want[i]`
    /// elsewhere. Used to pick padded panel shapes.
    fn best_panel(&self, op: &str, want: &[usize], exact: &[bool]) -> Option<Vec<usize>> {
        let mut best: Option<Vec<usize>> = None;
        for dims in self.rt.manifest().dims_for(op, self.family) {
            if dims.len() != want.len() {
                continue;
            }
            let ok = dims.iter().zip(want).zip(exact).all(|((&d, &w), &ex)| {
                if ex {
                    d == w
                } else {
                    d >= w
                }
            });
            if !ok {
                continue;
            }
            let waste: usize = dims.iter().product();
            if best.as_ref().map_or(true, |b| waste < b.iter().product::<usize>()) {
                best = Some(dims);
            }
        }
        best
    }

    /// Cut `src` (padded to multiples of `t`) into row-major `t×t` tiles.
    /// Returns (tiles, tiles_per_row_of_blocks).
    fn tilize(src: &LocalMatrix, t: usize) -> (Vec<Vec<f64>>, usize, usize) {
        let br = src.rows().div_ceil(t);
        let bc = src.cols().div_ceil(t);
        let mut tiles = vec![vec![0.0; t * t]; br * bc];
        for i in 0..src.rows() {
            let row = src.row(i);
            let bi = i / t;
            let ri = i % t;
            for bj in 0..bc {
                let j0 = bj * t;
                let j1 = (j0 + t).min(src.cols());
                tiles[bi * bc + bj][ri * t..ri * t + (j1 - j0)]
                    .copy_from_slice(&row[j0..j1]);
            }
        }
        (tiles, br, bc)
    }

    /// Write a `t×t` tile back into `dst` at block (bi, bj), clipping.
    fn untile(dst: &mut LocalMatrix, tile: &[f64], t: usize, bi: usize, bj: usize) {
        let i0 = bi * t;
        let j0 = bj * t;
        let i1 = (i0 + t).min(dst.rows());
        let j1 = (j0 + t).min(dst.cols());
        for i in i0..i1 {
            dst.row_mut(i)[j0..j1]
                .copy_from_slice(&tile[(i - i0) * t..(i - i0) * t + (j1 - j0)]);
        }
    }
}

impl Engine for XlaEngine {
    fn kind(&self) -> EngineKind {
        if self.family == "pallas" {
            EngineKind::Pallas
        } else {
            EngineKind::Xla
        }
    }

    fn gemm(
        &mut self,
        variant: GemmVariant,
        c: &mut LocalMatrix,
        a: &LocalMatrix,
        b: &LocalMatrix,
    ) -> crate::Result<()> {
        let (m, n, k) = variant.problem_dims(a, b);
        anyhow::ensure!(
            (c.rows(), c.cols()) == (m, n),
            "gemm {variant:?}: c is {}x{}, want {m}x{n}",
            c.rows(),
            c.cols()
        );
        let t = self.tile;
        let name = self
            .artifact(variant.op_name(), &[t, t, t])
            .with_context_none(format!("no {} artifact at tile {t}", variant.op_name()))?;
        let shape = [t, t];

        // Pre-cut operands into tiles once; note TN/NT store the panels
        // transposed, so block indices swap for A (TN) / B (NT).
        let (a_tiles, a_br, a_bc) = Self::tilize(a, t);
        let (b_tiles, b_br, b_bc) = Self::tilize(b, t);
        let kb = k.div_ceil(t);
        let (mb, nb) = (m.div_ceil(t), n.div_ceil(t));

        for bi in 0..mb {
            for bj in 0..nb {
                // accumulator tile seeded from C (clipped, zero-padded)
                let mut acc = vec![0.0; t * t];
                {
                    let i1 = ((bi * t) + t).min(m);
                    let j1 = ((bj * t) + t).min(n);
                    for i in bi * t..i1 {
                        let row = c.row(i);
                        acc[(i - bi * t) * t..(i - bi * t) * t + (j1 - bj * t)]
                            .copy_from_slice(&row[bj * t..j1]);
                    }
                }
                for bk in 0..kb {
                    let a_tile = match variant {
                        GemmVariant::NN | GemmVariant::NT => {
                            debug_assert!(bi < a_br && bk < a_bc);
                            &a_tiles[bi * a_bc + bk]
                        }
                        GemmVariant::TN => {
                            debug_assert!(bk < a_br && bi < a_bc);
                            &a_tiles[bk * a_bc + bi]
                        }
                    };
                    let b_tile = match variant {
                        GemmVariant::NN | GemmVariant::TN => {
                            debug_assert!(bk < b_br && bj < b_bc);
                            &b_tiles[bk * b_bc + bj]
                        }
                        GemmVariant::NT => {
                            debug_assert!(bj < b_br && bk < b_bc);
                            &b_tiles[bj * b_bc + bk]
                        }
                    };
                    let out = self.rt.run1(
                        &name,
                        &[
                            (acc.as_slice(), shape.as_slice()),
                            (a_tile.as_slice(), shape.as_slice()),
                            (b_tile.as_slice(), shape.as_slice()),
                        ],
                    )?;
                    acc = out.data;
                }
                Self::untile(c, &acc, t, bi, bj);
            }
        }
        Ok(())
    }

    fn gram_matvec(
        &mut self,
        a: &LocalMatrix,
        v: &LocalMatrix,
        reg: f64,
    ) -> crate::Result<LocalMatrix> {
        let (rows, k) = (a.rows(), a.cols());
        let c = v.cols();
        anyhow::ensure!(v.rows() == k, "gram_matvec shape mismatch");

        // Prefer a fused panel artifact: dims = (panel_rows, K_pad, C_pad).
        if let Some(dims) = self.best_panel("gram_matvec", &[1, k, c], &[false, false, false]) {
            let (pm, pk, pc) = (dims[0], dims[1], dims[2]);
            let name = self.artifact("gram_matvec", &dims).unwrap();
            let v_pad = v.padded(pk, pc);
            let v_shape = [pk, pc];
            let mut acc = vec![0.0; pk * pc];
            let mut first = true;
            let mut i0 = 0;
            while i0 < rows {
                let i1 = (i0 + pm).min(rows);
                let panel = a.slice_rows(i0, i1).padded(pm, pk);
                // reg·v must be added exactly once across panels
                let reg_now = [[if first { reg } else { 0.0 }]];
                let out = self.rt.run1(
                    &name,
                    &[
                        (panel.data(), [pm, pk].as_slice()),
                        (v_pad.data(), v_shape.as_slice()),
                        (&reg_now[0], [1, 1].as_slice()),
                    ],
                )?;
                for (dst, src) in acc.iter_mut().zip(&out.data) {
                    *dst += src;
                }
                first = false;
                i0 = i1;
            }
            if first {
                // zero-row panel: result is just reg·v
                let mut out = v.clone();
                out.scale(reg);
                return Ok(out);
            }
            return Ok(LocalMatrix::from_data(pk, pc, acc).shrunk(k, c));
        }

        // Fallback: compose from GEMM tiles (still the XLA path).
        let mut av = LocalMatrix::zeros(rows, c);
        self.gemm(GemmVariant::NN, &mut av, a, v)?;
        let mut out = v.clone();
        out.scale(reg);
        self.gemm(GemmVariant::TN, &mut out, a, &av)?;
        Ok(out)
    }

    fn gram_matvec_keyed(
        &mut self,
        key: u64,
        a: &LocalMatrix,
        v: &LocalMatrix,
        reg: f64,
    ) -> crate::Result<LocalMatrix> {
        let (rows, k) = (a.rows(), a.cols());
        let c = v.cols();
        anyhow::ensure!(v.rows() == k, "gram_matvec shape mismatch");
        let Some(dims) = self.best_panel("gram_matvec", &[1, k, c], &[false, false, false])
        else {
            // no fused artifact: tile-composition path, uncached
            return self.gram_matvec(a, v, reg);
        };
        let (pm, pk, pc) = (dims[0], dims[1], dims[2]);
        let name = self.artifact("gram_matvec", &dims).unwrap();
        let n_panels = rows.div_ceil(pm).max(1);

        // upload-once: the padded A panels live on device under (key, i)
        for p in 0..n_panels {
            if !self.operand_cache.contains_key(&(key, p)) {
                let i0 = p * pm;
                let i1 = (i0 + pm).min(rows);
                let panel = a.slice_rows(i0, i1).padded(pm, pk);
                let buf = self.rt.upload(panel.data(), &[pm, pk])?;
                self.operand_cache_bytes += buf.bytes();
                if self.operand_cache_bytes > OPERAND_CACHE_CAP_BYTES {
                    log::warn!(
                        "operand cache exceeded {} MiB; clearing",
                        OPERAND_CACHE_CAP_BYTES >> 20
                    );
                    self.operand_cache.clear();
                    self.operand_cache_bytes = buf.bytes();
                }
                self.operand_cache.insert((key, p), buf);
            }
        }

        let v_pad = v.padded(pk, pc);
        let mut acc = vec![0.0; pk * pc];
        for p in 0..n_panels {
            // reg·v enters exactly once (first panel)
            let reg_now = [[if p == 0 { reg } else { 0.0 }]];
            let v_buf = self.rt.upload(v_pad.data(), &[pk, pc])?;
            let reg_buf = self.rt.upload(&reg_now[0], &[1, 1])?;
            let a_buf = &self.operand_cache[&(key, p)];
            let out = self.rt.run1_b(&name, &[a_buf, &v_buf, &reg_buf])?;
            for (dst, src) in acc.iter_mut().zip(&out.data) {
                *dst += src;
            }
        }
        Ok(LocalMatrix::from_data(pk, pc, acc).shrunk(k, c))
    }

    fn rff_expand(
        &mut self,
        x: &LocalMatrix,
        omega: &LocalMatrix,
        bias: &[f64],
        scale: f64,
    ) -> crate::Result<LocalMatrix> {
        let (rows, k0) = (x.rows(), x.cols());
        let d = omega.cols();
        anyhow::ensure!(omega.rows() == k0 && bias.len() == d, "rff shape mismatch");

        // Panel artifact dims = (panel_rows, K0_pad, D_chunk); D is chunked
        // (cos is elementwise in d, so chunking is exact).
        if let Some(dims) = self.best_panel("rff_expand", &[1, k0, 1], &[false, false, false]) {
            let (pm, pk0, pd) = (dims[0], dims[1], dims[2]);
            let name = self.artifact("rff_expand", &dims).unwrap();
            let mut z = LocalMatrix::zeros(rows, d);
            let scale_arr = [[scale]];
            let mut j0 = 0;
            while j0 < d {
                let j1 = (j0 + pd).min(d);
                let om = omega.slice_cols(j0, j1).padded(pk0, pd);
                let mut bias_pad = vec![0.0; pd];
                bias_pad[..j1 - j0].copy_from_slice(&bias[j0..j1]);
                let mut i0 = 0;
                while i0 < rows {
                    let i1 = (i0 + pm).min(rows);
                    let panel = x.slice_rows(i0, i1).padded(pm, pk0);
                    let out = self.rt.run1(
                        &name,
                        &[
                            (panel.data(), [pm, pk0].as_slice()),
                            (om.data(), [pk0, pd].as_slice()),
                            (bias_pad.as_slice(), [1, pd].as_slice()),
                            (&scale_arr[0], [1, 1].as_slice()),
                        ],
                    )?;
                    let out = LocalMatrix::from_data(pm, pd, out.data);
                    for i in i0..i1 {
                        z.row_mut(i)[j0..j1]
                            .copy_from_slice(&out.row(i - i0)[..j1 - j0]);
                    }
                    i0 = i1;
                }
                j0 = j1;
            }
            return Ok(z);
        }

        // Fallback: projection through GEMM tiles, cos tail in rust.
        let mut z = LocalMatrix::zeros(rows, d);
        self.gemm(GemmVariant::NN, &mut z, x, omega)?;
        for i in 0..rows {
            let row = z.row_mut(i);
            for (j, vv) in row.iter_mut().enumerate() {
                *vv = scale * (*vv + bias[j]).cos();
            }
        }
        Ok(z)
    }

    fn cg_update(
        &mut self,
        x: &mut LocalMatrix,
        r: &mut LocalMatrix,
        p: &LocalMatrix,
        q: &LocalMatrix,
        alpha: &[f64],
    ) -> crate::Result<()> {
        let (rows, cols) = (x.rows(), x.cols());
        anyhow::ensure!(alpha.len() == cols, "alpha length mismatch");

        if let Some(dims) = self.best_panel("cg_update", &[1, cols], &[false, false]) {
            let (pm, pc) = (dims[0], dims[1]);
            let name = self.artifact("cg_update", &dims).unwrap();
            let mut alpha_pad = vec![0.0; pc];
            alpha_pad[..cols].copy_from_slice(alpha);
            let mut i0 = 0;
            while i0 < rows {
                let i1 = (i0 + pm).min(rows);
                let xs = x.slice_rows(i0, i1).padded(pm, pc);
                let rs = r.slice_rows(i0, i1).padded(pm, pc);
                let ps = p.slice_rows(i0, i1).padded(pm, pc);
                let qs = q.slice_rows(i0, i1).padded(pm, pc);
                let shape = [pm, pc];
                let out = self.rt.run(
                    &name,
                    &[
                        (xs.data(), shape.as_slice()),
                        (rs.data(), shape.as_slice()),
                        (ps.data(), shape.as_slice()),
                        (qs.data(), shape.as_slice()),
                        (alpha_pad.as_slice(), [1, pc].as_slice()),
                    ],
                )?;
                anyhow::ensure!(out.len() == 2, "cg_update returns 2 outputs");
                let xn = LocalMatrix::from_data(pm, pc, out[0].data.clone())
                    .shrunk(i1 - i0, cols);
                let rn = LocalMatrix::from_data(pm, pc, out[1].data.clone())
                    .shrunk(i1 - i0, cols);
                x.write_rows(i0, &xn);
                r.write_rows(i0, &rn);
                i0 = i1;
            }
            return Ok(());
        }

        // Fallback: plain loops (memory-bound op; no artifact exported for
        // this width).
        for i in 0..rows {
            let xr = x.row_mut(i);
            let pr = p.row(i);
            for j in 0..cols {
                xr[j] += alpha[j] * pr[j];
            }
            let rr = r.row_mut(i);
            let qr = q.row(i);
            for j in 0..cols {
                rr[j] -= alpha[j] * qr[j];
            }
        }
        Ok(())
    }

    fn exec_stats(&self) -> (u64, f64) {
        (self.rt.exec_calls, self.rt.exec_secs)
    }
}

/// `Option::context` helper that avoids importing anyhow's trait just for
/// one call site.
trait WithContextNone<T> {
    fn with_context_none(self, msg: String) -> crate::Result<T>;
}

impl<T> WithContextNone<T> for Option<T> {
    fn with_context_none(self, msg: String) -> crate::Result<T> {
        self.ok_or_else(|| anyhow::anyhow!(msg))
    }
}

// round_up is used by callers sizing padded buffers; keep the import alive.
const _: fn(usize, usize) -> usize = round_up;
