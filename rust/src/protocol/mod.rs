//! The Alchemist wire protocol (paper §3.1.2–3.2).
//!
//! Two channels, exactly as in the paper:
//!
//! * a **control** socket between the application driver and the Alchemist
//!   driver — handshakes, library registration, matrix-handle management,
//!   task invocation ([`ControlMsg`]);
//! * **data** sockets between application executors and Alchemist workers —
//!   matrix rows as raw little-endian f64 byte sequences ([`DataMsg`]).
//!
//! Everything is length-prefixed binary (serde is unavailable offline, and
//! the paper's transfer path is byte-oriented row shipping anyway — a
//! hand-rolled codec *is* the faithful reproduction).

pub mod fabric;
pub mod message;
pub mod value;
pub mod wire;

pub use fabric::{FabricFrame, WireOutput, WorkMsg, FABRIC_DATA_HEADER_LEN};
pub use message::{
    max_rows_per_frame_for, ControlMsg, DataMsg, DataMsgRef, DataMsgView, MatrixInfo,
    TaskProgress, TaskState, DEFAULT_PRIORITY, ROWS_HEADER_LEN,
};
pub use value::{Params, Value};
pub use wire::{copy_le_f64s, le_f64s_to_vec, ProtocolError, Reader, Writer};

/// Protocol version; bumped on any wire-format change, checked in the
/// handshake. v2: worker-group negotiation (`request_workers` /
/// `granted_workers`) on the handshake. v3: streaming ranged pulls
/// (`PullRows` answered by `RowsData`* + `PullDone`) and per-session
/// transfer negotiation (`rows_per_frame` / `buf_bytes` on the handshake,
/// effective values echoed in the ack). v4: asynchronous tasks — the
/// blocking `RunTask`/`TaskDone` pair becomes `SubmitTask` →
/// `TaskSubmitted { task_id }` with `TaskStatus`/`CancelTask`/`WaitTask`
/// over the `Queued → Running → Done | Failed | Cancelled` state machine
/// (see `docs/tasks.md`). v5: fault-tolerant collectives — `CancelTask`
/// gains `hard_after_ms` (elided at 0, so the default cancel keeps the
/// v4 wire shape): after the cooperative grace period the server poisons
/// the task's group communicator and the routine is forcibly unwound at
/// its next collective; failures are reported root-cause-first (the rank
/// that failed vs the peers its failure unwound). v6: vectored frame
/// sends (`writev` of header + borrowed payload) — an implementation
/// change with no wire-format delta, versioned for the bench
/// provenance trail. v7: the out-of-core storage plane —
/// `LoadMatrix`/`LoadDone` direct file ingest (workers map their shard
/// of an `hdf5sim` file server-side; zero payload bytes on the client
/// connection) and column-range pulls (`PullRows` gains
/// `start_col`/`sel_cols`, elided at full width so default pulls keep
/// the v6 wire shape). See `docs/storage.md`. v8: the network rank
/// fabric — worker ranks may run as separate OS processes
/// (`alchemist worker --connect`): a coordinator⇄worker control channel
/// ([`WorkMsg`]: attach handshake, mesh brokering, remote task dispatch
/// and store management) and rank⇄rank mesh frames ([`FabricFrame`])
/// carrying the collectives' point-to-point messages peer-to-peer. The
/// client-facing control/data channels are unchanged in shape; versioned
/// because a v8 coordinator and its worker processes must agree on the
/// new channels. See `docs/fabric.md`. v9: the serving-grade scheduler —
/// the handshake gains `priority` (elided at the default class, so
/// default clients keep the v8 wire shape; clamped server-side by
/// `scheduler.max_priority`), sessions run up to
/// `scheduler.tasks_per_group` concurrent tasks over per-task tag lanes
/// ([`WorkMsg::RunTask`] carries the lane; [`FabricFrame::Poison`] and
/// `MeshPoison` become lane-scoped; `MeshRetire` retires a finished
/// task's lane), and `SubscribeMetrics` streams push-based
/// `MetricsSnapshot` JSON frames (admission depth per class, task
/// gauges, queue-wait stats, per-task progress). See
/// `docs/scheduler.md`. v10: survivable sessions — the handshake ack
/// gains a `session_token` (elided at 0, so pre-v10 decoders still
/// parse the frame) and a dropped client may `Reattach{token}` within
/// `scheduler.session_linger_s` to re-list its tasks and collect
/// retained results (`ReattachAck`); `FetchReady` may carry refreshed
/// worker pull addresses (elided when unchanged) so results survive
/// rank replacement; the coordinator⇄worker channel gains
/// `StoreRestore` (replay a dead rank's checkpointed shard onto a
/// spare) and `StoreStats` (leak accounting for remote ranks). See
/// `docs/recovery.md`.
pub const PROTOCOL_VERSION: u32 = 10;
