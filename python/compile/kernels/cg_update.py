"""L1: fused conjugate-gradient pair-AXPY kernel.

One CG iteration on the normal equations updates the iterate and the
residual with the same step scalars: ``X += alpha*P; R -= alpha*Q`` (one
alpha per right-hand-side column, since the speech problem is a block solve
with 147 label columns). Fusing the pair halves the number of passes over
the [D, C] state matrices — on a TPU both updates read their operand tiles
once from HBM and write once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block


def _cg_update_kernel(x_ref, r_ref, p_ref, q_ref, alpha_ref, xo_ref, ro_ref):
    alpha = alpha_ref[...]  # [1, bn] row, broadcast down the tile
    xo_ref[...] = x_ref[...] + alpha * p_ref[...]
    ro_ref[...] = r_ref[...] - alpha * q_ref[...]


def make_cg_update(m: int, n: int, *, dtype=jnp.float64, block: int = 128,
                   interpret: bool = True):
    """Build ``fn(x, r, p, q, alpha[1,n]) -> (x + alpha*p, r - alpha*q)``."""
    bm = _pick_block(m, block)
    bn = _pick_block(n, block)
    grid = (m // bm, n // bn)

    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    row = pl.BlockSpec((1, bn), lambda i, j: (0, j))

    call = pl.pallas_call(
        _cg_update_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile, row],
        out_specs=[tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), dtype),
            jax.ShapeDtypeStruct((m, n), dtype),
        ],
        interpret=interpret,
    )

    def cg_update(x, r, p, q, alpha):
        for t in (x, r, p, q):
            assert t.shape == (m, n)
        assert alpha.shape == (1, n)
        return call(x, r, p, q, alpha)

    return cg_update
