//! Property + behavioral tests for the Spark stand-in: the overhead model
//! must charge what the config says, and the baselines must stay
//! numerically equal to the MPI-side solvers (same math, different cost).

use alchemist::config::Config;
use alchemist::distmat::LocalMatrix;
use alchemist::linalg::{CgOptions, RffMap, SvdOptions};
use alchemist::sparklite::{mllib, IndexedRowMatrix, SparkEngine};
use alchemist::testkit::{props, Gen};

fn quiet_engine(executors: usize) -> SparkEngine {
    let mut cfg = Config::default();
    cfg.overhead.scheduler_delay_s = 0.0;
    cfg.overhead.task_launch_s = 0.0;
    let mut e = SparkEngine::new(executors, &cfg);
    e.inject_real_delays = false;
    e
}

fn random_matrix(g: &mut Gen, r: usize, c: usize) -> LocalMatrix {
    let data = g.vec_normal(r * c);
    LocalMatrix::from_data(r, c, data)
}

#[test]
fn irm_roundtrip_any_partitioning() {
    props(60, |g| {
        let r = g.usize_in(1, 120);
        let c = g.usize_in(1, 12);
        let parts = g.usize_in(1, 10);
        let m = random_matrix(g, r, c);
        let irm = IndexedRowMatrix::from_local(&m, parts);
        assert_eq!(irm.num_partitions(), parts);
        assert_eq!(irm.to_local().unwrap(), m);
    });
}

#[test]
fn spark_cg_equals_mpi_cg_across_partitionings() {
    props(8, |g| {
        let n = g.usize_in(12, 50);
        let d = g.usize_in(2, 10);
        let c = g.usize_in(1, 3);
        let parts = g.usize_in(1, 6);
        let x = random_matrix(g, n, d);
        let y = random_matrix(g, n, c);
        let opts = CgOptions { lambda: 1e-3, tol: 1e-12, max_iters: 300 };

        let mut engine = quiet_engine(2);
        let spark = mllib::cg_solve(
            &mut engine,
            &IndexedRowMatrix::from_local(&x, parts),
            &IndexedRowMatrix::from_local(&y, parts),
            &opts,
        )
        .unwrap();

        let comms = alchemist::collectives::LocalComm::group(1, None);
        let mpi = alchemist::linalg::cg_solve(
            &comms[0],
            &mut alchemist::compute::NativeEngine::new(),
            &x,
            &y,
            n,
            &opts,
        )
        .unwrap();
        assert!(
            spark.w.max_abs_diff(&mpi.w) < 1e-7,
            "partitioning must not change the answer: {}",
            spark.w.max_abs_diff(&mpi.w)
        );
        // the overhead ledger grew with iterations: 1 stage per iter + XtY
        assert!(engine.stats().stages >= spark.iters + 1);
    });
}

#[test]
fn spark_svd_sigma_stable_under_partitioning() {
    props(6, |g| {
        let n = g.usize_in(24, 60);
        let k = g.usize_in(8, 16);
        let a = random_matrix(g, n, k);
        let opts = SvdOptions { rank: 3.min(k), steps: 0, seed: 77 };
        let mut sigmas = Vec::new();
        for parts in [1usize, 3, 5] {
            let mut engine = quiet_engine(2);
            let r = mllib::truncated_svd(
                &mut engine,
                &IndexedRowMatrix::from_local(&a, parts),
                &opts,
            )
            .unwrap();
            sigmas.push(r.sigma.clone());
        }
        for s in &sigmas[1..] {
            for (a, b) in s.iter().zip(&sigmas[0]) {
                assert!((a - b).abs() < 1e-8 * (1.0 + b));
            }
        }
    });
}

#[test]
fn overhead_gap_grows_with_scheduler_delay() {
    // the knob the calibration leans on: scheduler delay should move the
    // per-iteration cost roughly linearly (sim time ledger)
    let run = |delay: f64| {
        let mut cfg = Config::default();
        cfg.overhead.scheduler_delay_s = delay;
        cfg.overhead.task_launch_s = 0.0;
        let mut engine = SparkEngine::new(2, &cfg);
        engine.inject_real_delays = false;
        let x = LocalMatrix::from_fn(64, 8, |i, j| ((i * j) % 7) as f64 * 0.1 + 1.0);
        let y = LocalMatrix::from_fn(64, 2, |i, _| (i % 3) as f64);
        let opts = CgOptions { lambda: 1e-2, tol: 1e-10, max_iters: 40 };
        let r = mllib::cg_solve(
            &mut engine,
            &IndexedRowMatrix::from_local(&x, 4),
            &IndexedRowMatrix::from_local(&y, 4),
            &opts,
        )
        .unwrap();
        let sim_per_iter: f64 =
            r.iter_sim_secs.iter().sum::<f64>() / r.iter_sim_secs.len() as f64;
        sim_per_iter
    };
    let slow = run(0.4);
    let fast = run(0.04);
    assert!(
        slow > fast * 4.0,
        "10x scheduler delay should dominate sim per-iteration: {fast} -> {slow}"
    );
}

#[test]
fn memory_cap_is_a_hard_boundary() {
    props(20, |g| {
        let n = g.usize_in(10, 60);
        let d = g.usize_in(2, 16);
        let bytes = n * d * 8;
        let mut cfg = Config::default();
        // budget just below (fail) or above (pass) the requirement
        let below = g.bool();
        cfg.spark_driver_max_bytes = if below { bytes.saturating_sub(1) } else { bytes * 3 };
        let mut engine = SparkEngine::new(2, &cfg);
        engine.inject_real_delays = false;
        let x = random_matrix(g, n, d);
        let map = RffMap::generate(d, d, 1.0, 5);
        let res = mllib::rff_expand(&mut engine, &IndexedRowMatrix::from_local(&x, 2), &map);
        if below {
            assert!(res.is_err());
        } else {
            assert!(res.is_ok());
        }
    });
}
