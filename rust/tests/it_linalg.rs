//! Property tests: solver invariants across random problems and worker
//! counts (convergence, residuals, orthogonality, spectrum recovery).

use alchemist::collectives::{Communicator, LocalComm};
use alchemist::compute::NativeEngine;
use alchemist::distmat::{LocalMatrix, RowBlockLayout};
use alchemist::linalg::{
    cg_solve, cholesky_qr2, truncated_svd, CgOptions, SvdOptions,
};
use alchemist::testkit::{props, Gen};

fn random_matrix(g: &mut Gen, r: usize, c: usize) -> LocalMatrix {
    let data = g.vec_normal(r * c);
    LocalMatrix::from_data(r, c, data)
}

/// Run an SPMD closure over `workers` ranks on row-shards of `a` (and
/// optional `b`), collecting per-rank results.
fn spmd<T, F>(workers: usize, a: &LocalMatrix, b: Option<&LocalMatrix>, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(&LocalComm, LocalMatrix, Option<LocalMatrix>) -> T + Send + Sync + Clone + 'static,
{
    let layout = RowBlockLayout::even(a.rows(), a.cols(), workers);
    let comms = LocalComm::group(workers, None);
    let mut handles = Vec::new();
    for comm in comms {
        let (lo, hi) = layout.ranges[comm.rank()];
        let al = a.slice_rows(lo, hi);
        let bl = b.map(|m| m.slice_rows(lo, hi));
        let f = f.clone();
        handles.push(std::thread::spawn(move || f(&comm, al, bl)));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn cg_residual_certifies_solution() {
    props(12, |g| {
        let n = g.usize_in(10, 60);
        let d = g.usize_in(2, 12);
        let c = g.usize_in(1, 4);
        let workers = g.usize_in(1, 3);
        let lambda = g.f64_in(1e-4, 1e-1);
        let x = random_matrix(g, n, d);
        let y = random_matrix(g, n, c);
        let opts = CgOptions { lambda, tol: 1e-12, max_iters: 500 };

        let results = spmd(workers, &x, Some(&y), move |comm, xl, yl| {
            cg_solve(comm, &mut NativeEngine::new(), &xl, &yl.unwrap(), n, &opts).unwrap()
        });
        let w = &results[0].w;
        // certify: ‖(XᵀX + nλI)W − XᵀY‖ / ‖XᵀY‖ tiny
        let mut b = LocalMatrix::zeros(d, c);
        b.gemm_tn(&x, &y);
        let mut lhs = w.clone();
        lhs.scale(n as f64 * lambda);
        let mut xw = LocalMatrix::zeros(n, c);
        xw.gemm_nn(&x, w);
        lhs.gemm_tn(&x, &xw);
        lhs.axpy(-1.0, &b);
        let rel = lhs.fro_norm() / b.fro_norm().max(1e-300);
        assert!(rel < 1e-8, "relative normal-equation residual {rel}");
        // residual history is monotone-ish at the tail: final below tol
        assert!(results[0].residuals.last().unwrap() < &1e-10);
        // all ranks agree bitwise (replicated state)
        for r in &results[1..] {
            assert_eq!(&r.w, w);
        }
    });
}

#[test]
fn qr_invariants_random_problems() {
    props(12, |g| {
        let n = g.usize_in(8, 80);
        let k = g.usize_in(1, 8.min(n));
        let workers = g.usize_in(1, 3);
        let a = random_matrix(g, n, k);
        let a2 = a.clone();
        let results = spmd(workers, &a, None, move |comm, al, _| {
            let (q, r) = cholesky_qr2(comm, &mut NativeEngine::new(), &al).unwrap();
            (comm.rank(), q, r)
        });
        // reassemble Q
        let layout = RowBlockLayout::even(n, k, workers);
        let mut q = LocalMatrix::zeros(n, k);
        for (rank, ql, _) in &results {
            q.write_rows(layout.ranges[*rank].0, ql);
        }
        let r = &results[0].2;
        let mut qr = LocalMatrix::zeros(n, k);
        qr.gemm_nn(&q, r);
        assert!(qr.max_abs_diff(&a2) < 1e-8);
        let mut qtq = LocalMatrix::zeros(k, k);
        qtq.gemm_tn(&q, &q);
        assert!(qtq.max_abs_diff(&LocalMatrix::identity(k)) < 1e-9);
    });
}

#[test]
fn svd_invariants_random_spectra() {
    props(8, |g| {
        let n = g.usize_in(30, 80);
        let kdim = g.usize_in(10, 24);
        let rank = g.usize_in(1, 5);
        let workers = g.usize_in(1, 3);
        let a = random_matrix(g, n, kdim);
        let a2 = a.clone();
        let opts = SvdOptions { rank, steps: 0, seed: g.u64() };

        let results = spmd(workers, &a, None, move |comm, al, _| {
            let r = truncated_svd(comm, &mut NativeEngine::new(), &al, &opts).unwrap();
            (comm.rank(), r)
        });
        let r0 = &results[0].1;
        // descending, nonnegative
        for w in r0.sigma.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(r0.sigma.iter().all(|&s| s >= 0.0));
        // V orthonormal
        let mut vtv = LocalMatrix::zeros(rank, rank);
        vtv.gemm_tn(&r0.v, &r0.v);
        assert!(vtv.max_abs_diff(&LocalMatrix::identity(rank)) < 1e-7);
        // Rayleigh check: σ² == vᵀ(AᵀA)v per vector
        let mut g_mat = LocalMatrix::zeros(kdim, kdim);
        g_mat.gemm_tn(&a2, &a2);
        for kk in 0..rank {
            let v = r0.v.slice_cols(kk, kk + 1);
            let mut gv = LocalMatrix::zeros(kdim, 1);
            gv.gemm_nn(&g_mat, &v);
            let mut vgv = LocalMatrix::zeros(1, 1);
            vgv.gemm_tn(&v, &gv);
            let sig2 = r0.sigma[kk] * r0.sigma[kk];
            assert!(
                (vgv.get(0, 0) - sig2).abs() < 1e-6 * (1.0 + sig2),
                "rayleigh mismatch: {} vs {sig2}",
                vgv.get(0, 0)
            );
        }
    });
}

#[test]
fn tridiag_spectrum_shift_invariance() {
    props(50, |g| {
        let n = g.usize_in(1, 40);
        let d = g.vec_normal(n);
        let e = g.vec_normal(n.saturating_sub(1));
        let shift = g.f64_in(-5.0, 5.0);
        let (vals, _) = alchemist::linalg::tridiag::tql2(&d, &e).unwrap();
        let d2: Vec<f64> = d.iter().map(|x| x + shift).collect();
        let (vals2, _) = alchemist::linalg::tridiag::tql2(&d2, &e).unwrap();
        for (a, b) in vals.iter().zip(&vals2) {
            assert!((a + shift - b).abs() < 1e-8 * (1.0 + b.abs()));
        }
    });
}
