//! Weak-scaling truncated SVD (paper Figure 3): column-replicate the base
//! ocean matrix ×{1,2,4,8} while doubling workers, report load / SVD /
//! send-to-client time per size. Scaling shape is read from the simulated
//! cluster column (one core here; DESIGN.md §2).
//!
//! ```sh
//! cargo run --release --example scale_svd -- \
//!     [--cells 2048] [--times 256] [--rank 20] [--engine xla]
//! ```

use alchemist::cli::Args;
use alchemist::client::AlchemistContext;
use alchemist::config::Config;
use alchemist::coordinator::AlchemistServer;
use alchemist::metrics::Table;
use alchemist::protocol::Params;
use alchemist::util::fmt;
use alchemist::workloads::OceanSpec;

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let mut cfg = Config::default();
    if let Some(engine) = args.get("engine") {
        cfg.apply("engine", engine)?;
    }
    let cells = args.get_usize("cells", 2_048)?;
    let times = args.get_usize("times", 256)?;
    let rank = args.get_usize("rank", 20)?;
    let steps = args.get_usize("steps", 48)?;
    let replicas = args.get_usize_list("replicas", &[1, 2, 4, 8])?;
    let workers_list = args.get_usize_list("workers", &[2, 4, 8, 16])?;
    anyhow::ensure!(
        replicas.len() == workers_list.len(),
        "--replicas and --workers must have equal length"
    );

    let spec = OceanSpec { cells, times, ..OceanSpec::default() };
    let dir = std::env::temp_dir().join("alchemist-ocean");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("ocean_{cells}x{times}.bin"));
    if !path.exists() {
        let bytes = spec.write_file(&path)?;
        println!("wrote base field {} to {path:?}", fmt::bytes(bytes));
    }

    let mut table = Table::new(
        "scale_svd: Figure 3 weak scaling (size and workers double together)",
        &[
            "size", "workers", "load (s)", "replicate (s)", "svd (s)",
            "svd sim (s)", "send S<=A (s)", "sigma[0]",
        ],
    );

    for (&rep, &workers) in replicas.iter().zip(&workers_list) {
        println!("\n== replicas x{rep}, {workers} workers ==");
        let server = AlchemistServer::start(cfg.clone(), workers)?;
        let mut ac = AlchemistContext::connect(&server.control_addr, &cfg, 2)?;
        ac.register_library("elemental", "builtin:elemental")?;

        let load = ac.run_task(
            "elemental",
            "load_hdf5",
            Params::new().with_str("path", path.to_str().unwrap()),
        )?;
        let mut al_a = load.output("A")?.clone();
        let load_secs = load.timing("load");

        let mut rep_secs = 0.0;
        if rep > 1 {
            let r = ac.run_task(
                "elemental",
                "replicate_cols",
                Params::new().with_matrix("A", al_a.id).with_i64("times", rep as i64),
            )?;
            rep_secs = r.timing("replicate");
            al_a = r.output("A_rep")?.clone();
        }
        let bytes = al_a.size_bytes();

        let res = ac.run_task(
            "elemental",
            "truncated_svd",
            Params::new()
                .with_matrix("A", al_a.id)
                .with_i64("rank", rank as i64)
                .with_i64("steps", steps as i64),
        )?;
        let svd_secs = res.timing("compute");
        let svd_sim = res.timing("sim_secs");

        // send U, S, V to the client (one executor, like the paper)
        let mut ac1 = ac;
        ac1.executors = 1;
        let (_, su) = ac1.to_indexed_row_matrix(res.output("U")?, 1)?;
        let (_, ss) = ac1.to_indexed_row_matrix(res.output("S")?, 1)?;
        let (_, sv) = ac1.to_indexed_row_matrix(res.output("V")?, 1)?;
        let send_secs = su.secs + ss.secs + sv.secs;

        let sigma0 = match res.scalars.get("sigma") {
            Some(alchemist::protocol::Value::F64s(v)) if !v.is_empty() => v[0],
            _ => f64::NAN,
        };
        table.row(&[
            fmt::bytes(bytes as u64),
            workers.to_string(),
            format!("{load_secs:.2}"),
            format!("{rep_secs:.2}"),
            format!("{svd_secs:.2}"),
            format!("{svd_sim:.2}"),
            format!("{send_secs:.3}"),
            format!("{sigma0:.2}"),
        ]);

        ac1.shutdown_server()?;
        server.shutdown_on_request();
    }

    println!();
    table.print();
    println!(
        "(paper Fig 3 shape: simulated SVD time ~flat as size and workers double \
         together; send-to-client grows with output size)"
    );
    Ok(())
}
