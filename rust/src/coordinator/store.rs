//! Per-worker matrix storage: each worker rank holds its row-block of
//! every live distributed matrix (the server-side half of the `AlMatrix`
//! proxy scheme — data stays put between routines; only handles travel).
//!
//! Blocks are namespaced by owning session: matrix ids are globally
//! unique (the driver hands them out from one counter), but every block
//! records the session that created it and which slot of the layout this
//! worker fills (the session's *group-local* rank — with session-scoped
//! worker groups a worker's global rank no longer indexes
//! `layout.ranges`). Session teardown frees exactly that session's
//! blocks without touching any other tenant's.

use std::collections::HashMap;

use crate::distmat::{LocalMatrix, RowBlockLayout};

/// One worker's block of a distributed matrix.
#[derive(Debug, Clone)]
pub struct Block {
    pub layout: RowBlockLayout,
    /// Index of this worker's range in `layout.ranges`: the owning
    /// session's group-local rank for this worker.
    pub slot: usize,
    /// Session that owns this matrix.
    pub session: u64,
    /// This rank's rows (`layout.ranges[slot]`).
    pub local: LocalMatrix,
    /// Rows received so far during ingest (sealing checks the total).
    pub rows_received: u64,
    pub sealed: bool,
    pub name: String,
}

/// Matrix-id → block map for one worker rank.
#[derive(Debug, Default)]
pub struct MatrixStore {
    rank: usize,
    blocks: HashMap<u64, Block>,
}

impl MatrixStore {
    pub fn new(rank: usize) -> Self {
        MatrixStore { rank, blocks: HashMap::new() }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Allocate a zeroed, unsealed block for ingest. `slot` is this
    /// worker's index into `layout.ranges` (the session's group-local
    /// rank); `session` namespaces the block for teardown.
    pub fn alloc(
        &mut self,
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        slot: usize,
        session: u64,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            !self.blocks.contains_key(&id),
            "matrix id {id} already exists on rank {}",
            self.rank
        );
        anyhow::ensure!(
            slot < layout.ranges.len(),
            "slot {slot} outside layout of {} ranges",
            layout.ranges.len()
        );
        let (a, b) = layout.ranges[slot];
        let local = LocalMatrix::zeros(b - a, layout.cols);
        self.blocks.insert(
            id,
            Block {
                layout,
                slot,
                session,
                local,
                rows_received: 0,
                sealed: false,
                name: name.to_string(),
            },
        );
        Ok(())
    }

    /// Insert a fully-formed (already computed) block — routine outputs.
    pub fn insert(
        &mut self,
        id: u64,
        name: &str,
        layout: RowBlockLayout,
        local: LocalMatrix,
        slot: usize,
        session: u64,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            !self.blocks.contains_key(&id),
            "matrix id {id} already exists on rank {}",
            self.rank
        );
        anyhow::ensure!(
            slot < layout.ranges.len(),
            "slot {slot} outside layout of {} ranges",
            layout.ranges.len()
        );
        let (a, b) = layout.ranges[slot];
        anyhow::ensure!(
            local.rows() == b - a && local.cols() == layout.cols,
            "block shape {}x{} does not match layout slot {}x{} on rank {}",
            local.rows(),
            local.cols(),
            b - a,
            layout.cols,
            self.rank
        );
        let rows = local.rows() as u64;
        self.blocks.insert(
            id,
            Block {
                layout,
                slot,
                session,
                local,
                rows_received: rows,
                sealed: true,
                name: name.to_string(),
            },
        );
        Ok(())
    }

    /// Write incoming rows (global indices) into an unsealed block.
    pub fn write_rows(
        &mut self,
        id: u64,
        start_row: u64,
        ncols: usize,
        data: &[f64],
    ) -> crate::Result<()> {
        let block = self
            .blocks
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("matrix {id} not found on rank {}", self.rank))?;
        anyhow::ensure!(!block.sealed, "matrix {id} is sealed");
        anyhow::ensure!(
            ncols == block.layout.cols,
            "row width {ncols} != matrix cols {}",
            block.layout.cols
        );
        anyhow::ensure!(data.len() % ncols == 0, "ragged row payload");
        let nrows = data.len() / ncols;
        let (lo, hi) = block.layout.ranges[block.slot];
        let start = start_row as usize;
        anyhow::ensure!(
            start >= lo && start + nrows <= hi,
            "rows [{start}, {}) outside rank {} range [{lo}, {hi})",
            start + nrows,
            self.rank
        );
        let local_start = start - lo;
        block.local.data_mut()
            [local_start * ncols..(local_start + nrows) * ncols]
            .copy_from_slice(data);
        block.rows_received += nrows as u64;
        Ok(())
    }

    /// Read rows (global indices) out of a sealed block.
    pub fn read_rows(&self, id: u64, start_row: u64, nrows: usize) -> crate::Result<Vec<f64>> {
        let block = self.get(id)?;
        anyhow::ensure!(
            block.sealed,
            "matrix {id} is still being ingested (not sealed)"
        );
        let (lo, hi) = block.layout.ranges[block.slot];
        let start = start_row as usize;
        anyhow::ensure!(
            start >= lo && start + nrows <= hi,
            "rows [{start}, {}) outside rank {} range [{lo}, {hi})",
            start + nrows,
            self.rank
        );
        let ncols = block.layout.cols;
        let local_start = start - lo;
        Ok(block.local.data()
            [local_start * ncols..(local_start + nrows) * ncols]
            .to_vec())
    }

    pub fn seal(&mut self, id: u64) -> crate::Result<u64> {
        let block = self
            .blocks
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("matrix {id} not found"))?;
        block.sealed = true;
        Ok(block.rows_received)
    }

    pub fn get(&self, id: u64) -> crate::Result<&Block> {
        self.blocks
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("matrix {id} not found on rank {}", self.rank))
    }

    pub fn free(&mut self, id: u64) -> bool {
        self.blocks.remove(&id).is_some()
    }

    /// Drop every block owned by `session` (teardown); returns how many
    /// were freed. Other sessions' blocks are untouched.
    pub fn free_session(&mut self, session: u64) -> usize {
        let before = self.blocks.len();
        self.blocks.retain(|_, b| b.session != session);
        before - self.blocks.len()
    }

    pub fn ids(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.blocks.keys().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SID: u64 = 11;

    fn layout2() -> RowBlockLayout {
        RowBlockLayout::even(10, 3, 2)
    }

    #[test]
    fn ingest_flow() {
        let mut s = MatrixStore::new(1); // slot 1 owns rows [5, 10)
        s.alloc(7, "X", layout2(), 1, SID).unwrap();
        s.write_rows(7, 5, 3, &[1.0; 6]).unwrap(); // rows 5,6
        s.write_rows(7, 7, 3, &[2.0; 9]).unwrap(); // rows 7,8,9
        assert_eq!(s.seal(7).unwrap(), 5);
        let b = s.get(7).unwrap();
        assert_eq!(b.local.get(0, 0), 1.0);
        assert_eq!(b.local.get(2, 2), 2.0);
        // reads are in global coordinates
        assert_eq!(s.read_rows(7, 9, 1).unwrap(), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn slot_decouples_from_global_rank() {
        // a worker with global rank 5 fills slot 0 of a 2-range layout
        // (session-scoped groups: group-local rank != global rank)
        let mut s = MatrixStore::new(5);
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        s.write_rows(1, 0, 3, &[3.0; 15]).unwrap(); // rows [0, 5)
        assert_eq!(s.seal(1).unwrap(), 5);
        assert_eq!(s.read_rows(1, 4, 1).unwrap(), vec![3.0, 3.0, 3.0]);
        // rows of the other slot are rejected
        assert!(s.write_rows(1, 5, 3, &[0.0; 3]).is_err());
    }

    #[test]
    fn rejects_bad_writes() {
        let mut s = MatrixStore::new(0); // slot 0 owns rows [0, 5)
        s.alloc(1, "X", layout2(), 0, SID).unwrap();
        assert!(s.alloc(1, "X", layout2(), 0, SID).is_err()); // duplicate id
        assert!(s.alloc(2, "X", layout2(), 9, SID).is_err()); // bad slot
        assert!(s.write_rows(1, 4, 3, &[0.0; 6]).is_err()); // crosses range end
        assert!(s.write_rows(1, 0, 2, &[0.0; 2]).is_err()); // wrong width
        assert!(s.write_rows(2, 0, 3, &[0.0; 3]).is_err()); // unknown id
        s.seal(1).unwrap();
        assert!(s.write_rows(1, 0, 3, &[0.0; 3]).is_err()); // sealed
        assert!(s.read_rows(1, 4, 2).is_err()); // read crosses range
    }

    #[test]
    fn insert_checks_shape() {
        let mut s = MatrixStore::new(0);
        let l = layout2();
        assert!(s
            .insert(3, "W", l.clone(), LocalMatrix::zeros(4, 3), 0, SID)
            .is_err());
        s.insert(3, "W", l, LocalMatrix::zeros(5, 3), 0, SID).unwrap();
        assert!(s.get(3).unwrap().sealed);
        assert!(s.free(3));
        assert!(!s.free(3));
    }

    #[test]
    fn free_session_is_scoped() {
        let mut s = MatrixStore::new(0);
        s.alloc(1, "A", layout2(), 0, 100).unwrap();
        s.alloc(2, "B", layout2(), 0, 100).unwrap();
        s.alloc(3, "C", layout2(), 1, 200).unwrap();
        assert_eq!(s.free_session(100), 2);
        assert_eq!(s.ids(), vec![3]);
        assert_eq!(s.free_session(100), 0);
        assert_eq!(s.free_session(200), 1);
        assert!(s.is_empty());
    }
}
