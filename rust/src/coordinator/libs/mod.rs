//! Built-in MPI-style libraries (the ALIs of paper §3.1.3).
//!
//! * [`skylark`] — the libSkylark stand-in: block CG on the normal
//!   equations + random-feature expansion (§4.1).
//! * [`elemental`] — the Elemental-routines stand-in: truncated SVD, QR,
//!   GEMM, file load, column replication, synthetic generation (§4.2).

pub mod elemental;
pub mod skylark;

use crate::distmat::{LocalMatrix, RowBlockLayout};

/// Slice a replicated matrix into this rank's row-block for output
/// registration (routines that produce replicated results — W, V, R —
/// still return them as distributed handles, matching the paper's
/// `AlMatrix` model where every output lives in Alchemist as a
/// distributed matrix).
pub fn distribute_replicated(
    m: &LocalMatrix,
    workers: usize,
    rank: usize,
) -> (RowBlockLayout, LocalMatrix) {
    let layout = RowBlockLayout::even(m.rows(), m.cols(), workers);
    let (a, b) = layout.ranges[rank];
    (layout.clone(), m.slice_rows(a, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribute_replicated_covers() {
        let m = LocalMatrix::from_fn(7, 2, |i, j| (i * 2 + j) as f64);
        let mut rebuilt = LocalMatrix::zeros(7, 2);
        for rank in 0..3 {
            let (layout, local) = distribute_replicated(&m, 3, rank);
            rebuilt.write_rows(layout.ranges[rank].0, &local);
        }
        assert_eq!(rebuilt, m);
    }
}
