//! `AlMatrix` — the client-side proxy for a matrix living in Alchemist
//! (paper §3.3.2: "matrix handles that act as proxies for the distributed
//! data sets stored in Alchemist").

/// A handle to a distributed matrix on the server. Cheap to clone and to
/// pass back into further routines; data only moves when the application
//  explicitly materializes it.
#[derive(Debug, Clone, PartialEq)]
pub struct AlMatrix {
    pub id: u64,
    pub rows: usize,
    pub cols: usize,
    pub name: String,
    /// Worker row ownership (`[start, end)` per rank) — lets executors
    /// push/pull rows to the right worker without asking the driver.
    pub row_ranges: Vec<(usize, usize)>,
}

impl AlMatrix {
    pub fn size_bytes(&self) -> usize {
        self.rows * self.cols * 8
    }

    /// Which worker rank owns global row `i`.
    pub fn owner_of(&self, i: usize) -> usize {
        debug_assert!(i < self.rows);
        self.row_ranges
            .iter()
            .position(|&(a, b)| a <= i && i < b)
            .expect("row not covered by any worker range")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_lookup() {
        let m = AlMatrix {
            id: 1,
            rows: 10,
            cols: 2,
            name: "X".into(),
            row_ranges: vec![(0, 4), (4, 10)],
        };
        assert_eq!(m.owner_of(0), 0);
        assert_eq!(m.owner_of(3), 0);
        assert_eq!(m.owner_of(4), 1);
        assert_eq!(m.owner_of(9), 1);
        assert_eq!(m.size_bytes(), 160);
    }
}
