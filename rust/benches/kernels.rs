//! Compute-plane kernel bench: GFLOP/s per kernel × shape × thread count
//! for the native engine's hot ops (the three GEMM storage variants plus
//! the fused `gram_matvec` / `cg_update` / `rff_expand`), with the
//! seed-era unpacked GEMM as the reference floor.
//!
//! Emits a machine-readable baseline with `--json PATH` —
//! `BENCH_compute.json` in the repo root is the committed reference every
//! compute PR is compared against (CI runs the `--quick` size, uploads
//! the artifact, and diffs it via `scripts/check_bench_baseline.py`; see
//! README "Pinning a benchmark baseline"). The checker also asserts the
//! expectations recorded per run, starting with: packed ≥ 2x seed at 512³
//! single-thread, and threads=4 ≥ 2x threads=1 on the same shape.
//!
//! Since v6 the sweep also reports the **runtime-dispatched ISA path**
//! (fallback vs AVX2 vs AVX-512) with one `gemm_nn_isa_*` cell per path
//! runnable on the host at the pinned 512³ shape — the checker asserts
//! the dispatched AVX2 kernel beats the portable fallback — and
//! `gemm_nn_auto` cells for the `engine = "auto"` cost-model dispatcher,
//! which must never lose to the packed native kernel it routes to.
//!
//! Flags: `--quick` (smoke sweep), `--runs N` (default 3),
//! `--threads 1,2,4`, `--json PATH`.

mod bench_common;

use alchemist::cli::Args;
use alchemist::compute::{DispatchEngine, Engine, GemmVariant, NativeEngine};
use alchemist::config::Config;
use alchemist::distmat::LocalMatrix;
use alchemist::metrics::{Stats, Table};
use alchemist::simd::{self, Isa};
use alchemist::util::prng::Rng;
use alchemist::util::timer::time;
use bench_common::{gemm_nn_seed, is_quick};

struct Cell {
    kernel: &'static str,
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
    secs: f64,
    gflops: f64,
}

fn random(seed: u64, r: usize, c: usize) -> LocalMatrix {
    let mut rng = Rng::new(seed);
    LocalMatrix::from_fn(r, c, |_, _| rng.normal())
}

/// Mean seconds of `reps` timed calls after one warmup.
fn measure(reps: usize, mut f: impl FnMut()) -> f64 {
    f(); // warm caches / pool threads
    let mut stats = Stats::new();
    for _ in 0..reps {
        let (_, secs) = time(&mut f);
        stats.push(secs);
    }
    stats.mean()
}

fn main() -> alchemist::Result<()> {
    alchemist::logging::init();
    let args = Args::from_env();
    let quick = is_quick(&args);
    let runs = args.get_usize("runs", 3)?;
    let threads_list = args.get_usize_list("threads", &[1, 2, 4])?;

    println!(
        "selected ISA path: {} (host supports {})",
        simd::selected().name(),
        simd::detected().name()
    );

    let mut cells: Vec<Cell> = Vec::new();

    // ---- GEMM family (plus the seed reference) ----
    // 512³ is the shape the acceptance thresholds are pinned on; keep it
    // in the quick sweep so every CI artifact carries it
    let gemm_shapes: &[(usize, usize, usize)] = if quick {
        &[(512, 512, 512)]
    } else {
        &[(256, 256, 256), (512, 512, 512), (1024, 1024, 1024), (4096, 64, 512)]
    };
    for &(m, n, k) in gemm_shapes {
        let a = random(1, m, k);
        let b = random(2, k, n);
        let at = a.transpose();
        let bt = b.transpose();
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let reps = if m * n * k > 256 << 20 { runs.min(2) } else { runs };

        // seed-era unpacked loop: single thread only (it had no pool)
        let secs = measure(reps, || {
            let mut c = LocalMatrix::zeros(m, n);
            gemm_nn_seed(&mut c, &a, &b);
        });
        cells.push(Cell {
            kernel: "gemm_nn_seed",
            m,
            n,
            k,
            threads: 1,
            secs,
            gflops: flops / secs / 1e9,
        });

        for &threads in &threads_list {
            let mut engine = NativeEngine::with_threads(threads);
            for (kernel, variant, opa, opb) in [
                ("gemm_nn", GemmVariant::NN, &a, &b),
                ("gemm_tn", GemmVariant::TN, &at, &b),
                ("gemm_nt", GemmVariant::NT, &a, &bt),
            ] {
                let secs = measure(reps, || {
                    let mut c = LocalMatrix::zeros(m, n);
                    engine.gemm(variant, &mut c, opa, opb).unwrap();
                });
                cells.push(Cell {
                    kernel,
                    m,
                    n,
                    k,
                    threads,
                    secs,
                    gflops: flops / secs / 1e9,
                });
            }
        }
    }

    // ---- runtime ISA dispatch, pinned shape only ----
    // one cell per path runnable on this host, all single-thread so the
    // comparison isolates the micro-kernel (the checker asserts the
    // dispatched avx2 cell >= the fallback cell; absent cells — e.g. a
    // non-AVX2 runner — downgrade that check to a skip)
    {
        let (m, n, k) = (512usize, 512usize, 512usize);
        let a = random(1, m, k);
        let b = random(2, k, n);
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        for isa in simd::available() {
            let kernel = match isa {
                Isa::Fallback => "gemm_nn_isa_fallback",
                Isa::Avx2 => "gemm_nn_isa_avx2",
                Isa::Avx512 => "gemm_nn_isa_avx512",
            };
            let mut engine = NativeEngine::with_threads(1);
            let secs = measure(runs, || {
                simd::with_isa(isa, || {
                    let mut c = LocalMatrix::zeros(m, n);
                    engine.gemm(GemmVariant::NN, &mut c, &a, &b).unwrap();
                })
            });
            cells.push(Cell {
                kernel,
                m,
                n,
                k,
                threads: 1,
                secs,
                gflops: flops / secs / 1e9,
            });
        }

        // the cost-model dispatcher on the same shape: `auto` routes
        // composed GEMM to the packed native kernels, so these cells must
        // track the gemm_nn cells — the checker gates auto >= packed.
        // (Missing XLA artifacts just degrade auto to native-only, which
        // is exactly the path being gated.)
        let cfg = Config::default();
        for &threads in &threads_list {
            let mut engine = DispatchEngine::new(&cfg, NativeEngine::with_threads(threads));
            let secs = measure(runs, || {
                let mut c = LocalMatrix::zeros(m, n);
                engine.gemm(GemmVariant::NN, &mut c, &a, &b).unwrap();
            });
            cells.push(Cell {
                kernel: "gemm_nn_auto",
                m,
                n,
                k,
                threads,
                secs,
                gflops: flops / secs / 1e9,
            });
        }
    }

    // ---- fused ops ----
    let (g_rows, g_d, g_nrhs) = if quick { (2048, 512, 32) } else { (8192, 512, 32) };
    let ga = random(3, g_rows, g_d);
    let gv = random(4, g_d, g_nrhs);
    // two GEMMs: A·v and Aᵀ(Av)
    let g_flops = 4.0 * g_rows as f64 * g_d as f64 * g_nrhs as f64;

    let (u_rows, u_cols) = if quick { (65_536, 32) } else { (262_144, 32) };
    let ux = random(5, u_rows, u_cols);
    let ur = random(6, u_rows, u_cols);
    let up = random(7, u_rows, u_cols);
    let uq = random(8, u_rows, u_cols);
    let ualpha: Vec<f64> = (0..u_cols).map(|j| 0.25 + j as f64 * 0.01).collect();
    // two FMAs per element across the x and r halves
    let u_flops = 4.0 * u_rows as f64 * u_cols as f64;

    let (r_rows, r_k0, r_d) = if quick { (1024, 440, 1024) } else { (4096, 440, 2048) };
    let rx = random(9, r_rows, r_k0);
    let romega = random(10, r_k0, r_d);
    let rbias: Vec<f64> = (0..r_d).map(|j| j as f64 * 0.006).collect();
    // GEMM flops only — the cos() epilogue is accounted in secs but not
    // in the flop count, so rff GFLOP/s understates the kernel by design
    let r_flops = 2.0 * r_rows as f64 * r_k0 as f64 * r_d as f64;

    for &threads in &threads_list {
        let mut engine = NativeEngine::with_threads(threads);

        let secs = measure(runs, || {
            let _ = engine.gram_matvec(&ga, &gv, 1e-3).unwrap();
        });
        cells.push(Cell {
            kernel: "gram_matvec",
            m: g_rows,
            n: g_nrhs,
            k: g_d,
            threads,
            secs,
            gflops: g_flops / secs / 1e9,
        });

        // clone once OUTSIDE the timed region (a 16 MB memcpy is
        // comparable to the memory-bound kernel and would pollute the
        // gated metric); repeated in-place updates just drift x/r
        // linearly, which doesn't change dense-FMA timing
        let (mut x, mut r) = (ux.clone(), ur.clone());
        let secs = measure(runs, || {
            engine.cg_update(&mut x, &mut r, &up, &uq, &ualpha).unwrap();
        });
        cells.push(Cell {
            kernel: "cg_update",
            m: u_rows,
            n: u_cols,
            k: 0,
            threads,
            secs,
            gflops: u_flops / secs / 1e9,
        });

        let secs = measure(runs, || {
            let _ = engine
                .rff_expand(&rx, &romega, &rbias, (2.0 / r_d as f64).sqrt())
                .unwrap();
        });
        cells.push(Cell {
            kernel: "rff_expand",
            m: r_rows,
            n: r_d,
            k: r_k0,
            threads,
            secs,
            gflops: r_flops / secs / 1e9,
        });
    }

    let mut table = Table::new(
        "kernels: native compute plane (GFLOP/s)",
        &["kernel", "m", "n", "k", "threads", "secs", "GFLOP/s"],
    );
    for c in &cells {
        table.row(&[
            c.kernel.to_string(),
            c.m.to_string(),
            c.n.to_string(),
            c.k.to_string(),
            c.threads.to_string(),
            format!("{:.4}", c.secs),
            format!("{:.2}", c.gflops),
        ]);
    }
    table.print();

    if let Some(path) = args.get("json") {
        write_json(path, quick, runs, &threads_list, simd::selected().name(), &cells)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

fn write_json(
    path: &str,
    quick: bool,
    runs: usize,
    threads_list: &[usize],
    isa: &str,
    cells: &[Cell],
) -> alchemist::Result<()> {
    let threads_json: Vec<String> = threads_list.iter().map(|t| t.to_string()).collect();
    let mut body = String::new();
    body.push_str("{\n");
    body.push_str("  \"bench\": \"kernels\",\n");
    body.push_str("  \"kind\": \"compute\",\n");
    body.push_str(
        "  \"units\": {\"secs\": \"mean wallclock seconds\", \"gflops\": \"1e9 flop/s\"},\n",
    );
    // "isa" records the runner's dispatched path for provenance; the
    // baseline checker's comparability key is (quick, runs, threads)
    // only, so baselines pinned before this field still compare
    body.push_str(&format!(
        "  \"config\": {{\"quick\": {quick}, \"runs\": {runs}, \"threads\": [{}], \
         \"isa\": \"{isa}\"}},\n",
        threads_json.join(", ")
    ));
    body.push_str("  \"expected\": {\n");
    body.push_str(
        "    \"packed_vs_seed\": \"gemm_nn (packed, threads=1) >= 2x gemm_nn_seed at 512x512x512\",\n",
    );
    body.push_str("    \"scaling\": \"gemm_nn threads=4 >= 2x threads=1 at 512x512x512\",\n");
    body.push_str(
        "    \"isa_dispatch\": \"gemm_nn_isa_avx2 >= 1.2x gemm_nn_isa_fallback at 512x512x512 threads=1 (skipped on non-AVX2 runners)\",\n",
    );
    body.push_str(
        "    \"auto_vs_packed\": \"gemm_nn_auto >= gemm_nn at 512x512x512 at every measured thread count\"\n",
    );
    body.push_str("  },\n");
    body.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"kernel\": \"{}\", \"m\": {}, \"n\": {}, \"k\": {}, \
             \"threads\": {}, \"secs\": {}, \"gflops\": {}}}{}\n",
            c.kernel,
            c.m,
            c.n,
            c.k,
            c.threads,
            json_num(c.secs),
            json_num(c.gflops),
            if i + 1 == cells.len() { "" } else { "," },
        ));
    }
    body.push_str("  ]\n");
    body.push_str("}\n");
    std::fs::write(path, body)?;
    Ok(())
}
